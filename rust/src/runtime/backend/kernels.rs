//! Native CPU kernels for the manifest's executable semantics.
//!
//! These implement, in plain Rust, the same math the AOT HLO graphs encode
//! (python/compile/model.py + kernels/ref.py document the contracts):
//! rmsnorm, causal RoPE attention, SwiGLU, the fake-quant weight/activation
//! blends, the reconstruction and rounding-commitment losses — plus the
//! *backward* rules the STE seams define (python/compile/ste.py):
//!
//! * activations: STE through round, LSQ step-size gradient chained into
//!   the learnable clip `alpha`;
//! * weights: STE pass-through, per-channel LSQ gradient for `s_w`, and
//!   `drho = g * s * Z` flowing into the LoRA factors.
//!
//! Parallelism: the persistent worker pool (`backend::pool`) splits work
//! across batch rows for the matmuls and across `(batch, head)` pairs for
//! attention. Every output row/head is written by exactly one task and
//! reduced sequentially within it, so results are bit-deterministic
//! regardless of thread count.
//!
//! Matmuls are cache-blocked: B is packed once per call into `NR`-wide
//! column panels (contiguous per reduction step) and an `MR x NR`
//! register-tiled micro-kernel accumulates each output tile with the
//! reduction index ascending — the *same per-element accumulation order as
//! the naive loops*, so blocked and naive kernels agree bit-for-bit on
//! finite inputs (property-tested in `tests/proptests.rs`).
//!
//! The packed-domain inner loops ([`qmatmul`] / [`qmatvec`]) additionally
//! dispatch on a one-time CPUID probe ([`simd_tier`]): scalar, SSE2 or
//! AVX2 decode+multiply-add tiles, forceable with
//! `CBQ_SIMD=scalar|sse2|avx2`. Every tier decodes codes to registers and
//! keeps the identical mul-then-add (never fused) per-element sequence,
//! so all tiers are bitwise-equal by construction.

use crate::quant::{rect_sigmoid, EPS, GAMMA, ZETA};

use super::pool;

pub use super::pool::num_threads;

// ---------------------------------------------------------------------------
// pool-backed parallel helpers
// ---------------------------------------------------------------------------

/// Apply `f(row_index, row)` to every `row_len` chunk of `out`, splitting
/// the rows across the persistent worker pool. Falls back to the serial
/// loop when the total work is too small to amortize dispatch.
pub fn par_rows<F>(out: &mut [f32], row_len: usize, work_per_row: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0 && out.len() % row_len == 0);
    let rows = out.len() / row_len;
    let threads = num_threads().min(rows.max(1));
    // below ~64k flops total the dispatch overhead dominates
    if threads <= 1 || rows * work_per_row < 65_536 {
        for (i, row) in out.chunks_mut(row_len).enumerate() {
            f(i, row);
        }
        return;
    }
    // fixed chunking (rows.div_ceil(threads) rows per task): the same
    // scheme the scoped-thread implementation used, kept for determinism
    let per = rows.div_ceil(threads);
    let fr = &f;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .chunks_mut(per * row_len)
        .enumerate()
        .map(|(ti, chunk)| {
            Box::new(move || {
                for (j, row) in chunk.chunks_mut(row_len).enumerate() {
                    fr(ti * per + j, row);
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool::run_scoped(tasks);
}

/// Map `f` over `0..n` on the worker pool, collecting owned results in
/// index order (used for per-head attention work, where each item returns
/// several buffers).
pub fn par_map<T, F>(n: usize, min_serial: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= min_serial {
        return (0..n).map(f).collect();
    }
    let per = n.div_ceil(threads);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let fr = &f;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .chunks_mut(per)
            .enumerate()
            .map(|(ti, chunk)| {
                Box::new(move || {
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        *slot = Some(fr(ti * per + j));
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::run_scoped(tasks);
    }
    out.into_iter().map(|o| o.expect("par_map slot unfilled")).collect()
}

// ---------------------------------------------------------------------------
// dense matmuls — cache-blocked with packed-B panels
// ---------------------------------------------------------------------------

/// Micro-kernel tile: MR output rows x NR output columns held in registers.
const MR: usize = 4;
const NR: usize = 8;

/// Below this many multiply-adds the packing + dispatch overhead beats the
/// cache win; fall through to the naive loops.
const BLOCK_MIN_MULS: usize = 4096;

/// `CBQ_NAIVE_KERNELS=1` forces the pre-blocking row-parallel loops — the
/// before/after instrument `benches/perf_runtime.rs` records.
fn force_naive() -> bool {
    use std::sync::OnceLock;
    static NAIVE: OnceLock<bool> = OnceLock::new();
    *NAIVE.get_or_init(|| {
        std::env::var("CBQ_NAIVE_KERNELS").map(|v| v == "1" || v == "true").unwrap_or(false)
    })
}

/// Pack the effective `[k, n]` B matrix into `ceil(n/NR)` column panels:
/// `panels[pj][p*NR + c] = B_eff[p][pj*NR + c]` (tail panel zero-padded).
/// `get(p, j)` abstracts the source layout (row-major B or transposed B).
fn pack_panels(get: impl Fn(usize, usize) -> f32, k: usize, n: usize) -> Vec<f32> {
    let n_panels = n.div_ceil(NR);
    let mut packed = vec![0.0f32; n_panels * k * NR];
    for pj in 0..n_panels {
        let j0 = pj * NR;
        let w = NR.min(n - j0);
        let panel = &mut packed[pj * k * NR..(pj + 1) * k * NR];
        for p in 0..k {
            for c in 0..w {
                panel[p * NR + c] = get(p, j0 + c);
            }
        }
    }
    packed
}

/// Blocked micro-kernel over a contiguous span of output rows.
///
/// `out_chunk` covers rows `[row0, row0 + out_chunk.len()/n)` of the
/// result. The A element for (global output row `r`, reduction step `p`)
/// is `a[r*a_stride + p]`, or `a[p*a_stride + r]` when `a_transposed`.
/// Accumulators start at zero and sum `p` ascending — the identical
/// per-element order as the naive loops, hence bit-identical results.
#[inline]
fn blocked_rows(
    out_chunk: &mut [f32],
    n: usize,
    row0: usize,
    k: usize,
    panels: &[f32],
    a: &[f32],
    a_stride: usize,
    a_transposed: bool,
) {
    let rows_total = out_chunk.len() / n;
    let n_panels = n.div_ceil(NR);
    for ib in (0..rows_total).step_by(MR) {
        let rows = MR.min(rows_total - ib);
        for pj in 0..n_panels {
            let j0 = pj * NR;
            let w = NR.min(n - j0);
            let panel = &panels[pj * k * NR..(pj + 1) * k * NR];
            let mut acc = [[0.0f32; NR]; MR];
            for p in 0..k {
                let brow = &panel[p * NR..p * NR + NR];
                if a_transposed {
                    // A element for (row r, step p) is a[p*stride + row]
                    let arow = &a[p * a_stride + row0 + ib..p * a_stride + row0 + ib + rows];
                    for r in 0..rows {
                        let av = arow[r];
                        for c in 0..NR {
                            acc[r][c] += av * brow[c];
                        }
                    }
                } else {
                    // A element for (row r, step p) is a[row*stride + p]
                    for r in 0..rows {
                        let av = a[(row0 + ib + r) * a_stride + p];
                        for c in 0..NR {
                            acc[r][c] += av * brow[c];
                        }
                    }
                }
            }
            for (r, acc_row) in acc.iter().enumerate().take(rows) {
                let base = (ib + r) * n + j0;
                out_chunk[base..base + w].copy_from_slice(&acc_row[..w]);
            }
        }
    }
}

/// Run `blocked_rows` over `out`, splitting MR-aligned row chunks across
/// the worker pool with the fixed chunking scheme.
fn blocked_parallel(
    out: &mut [f32],
    n: usize,
    k: usize,
    panels: &[f32],
    a: &[f32],
    a_stride: usize,
    a_transposed: bool,
) {
    let m = out.len() / n;
    let row_blocks = m.div_ceil(MR);
    let threads = num_threads().min(row_blocks.max(1));
    if threads <= 1 || 2 * m * k * n < 65_536 {
        blocked_rows(out, n, 0, k, panels, a, a_stride, a_transposed);
        return;
    }
    let per_rows = row_blocks.div_ceil(threads) * MR;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .chunks_mut(per_rows * n)
        .enumerate()
        .map(|(ti, chunk)| {
            Box::new(move || {
                blocked_rows(chunk, n, ti * per_rows, k, panels, a, a_stride, a_transposed);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool::run_scoped(tasks);
}

/// `A[m,k] @ B[k,n] -> [m,n]`: packed-panel blocked kernel, bit-identical
/// to [`matmul_naive`].
pub fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    if force_naive() || m * k * n < BLOCK_MIN_MULS {
        return matmul_naive(a, m, k, b, n);
    }
    let panels = pack_panels(|p, j| b[p * n + j], k, n);
    let mut out = vec![0.0f32; m * n];
    blocked_parallel(&mut out, n, k, &panels, a, k, false);
    out
}

/// `A[m,k] @ B^T` with `B: [n,k]` -> `[m,n]`. B's rows are the panel
/// columns, packed once so the micro-kernel reads both operands
/// contiguously. Bit-identical to [`matmul_transb_naive`].
pub fn matmul_transb(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    if force_naive() || m * k * n < BLOCK_MIN_MULS {
        return matmul_transb_naive(a, m, k, b, n);
    }
    let panels = pack_panels(|p, j| b[j * k + p], k, n);
    let mut out = vec![0.0f32; m * n];
    blocked_parallel(&mut out, n, k, &panels, a, k, false);
    out
}

/// `A^T @ B` with `A: [m,k]`, `B: [m,n]` -> `[k,n]` (reduction over `m`).
/// The micro-kernel reads MR consecutive A columns per step — contiguous,
/// where the naive loop strode by `k`. Bit-identical to
/// [`matmul_transa_naive`].
pub fn matmul_transa(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    if force_naive() || m * k * n < BLOCK_MIN_MULS {
        return matmul_transa_naive(a, m, k, b, n);
    }
    let panels = pack_panels(|p, j| b[p * n + j], m, n);
    let mut out = vec![0.0f32; k * n];
    blocked_parallel(&mut out, n, m, &panels, a, k, true);
    out
}

// ---------------------------------------------------------------------------
// naive row-parallel reference matmuls (small-size path + property oracle)
// ---------------------------------------------------------------------------

/// Row-parallel naive `A[m,k] @ B[k,n]` (the pre-blocking kernel).
pub fn matmul_naive(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    par_rows(&mut out, n.max(1), 2 * k * n, |i, orow| {
        let arow = &a[i * k..(i + 1) * k];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    });
    out
}

/// Row-parallel naive `A[m,k] @ B[n,k]^T`.
pub fn matmul_transb_naive(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    par_rows(&mut out, n.max(1), 2 * k * n, |i, orow| {
        let arow = &a[i * k..(i + 1) * k];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *o = acc;
        }
    });
    out
}

/// Row-parallel naive `A[m,k]^T @ B[m,n]`.
pub fn matmul_transa_naive(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    let mut out = vec![0.0f32; k * n];
    par_rows(&mut out, n.max(1), 2 * m * n, |kk, orow| {
        for i in 0..m {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    });
    out
}

// ---------------------------------------------------------------------------
// packed-domain quantized matmul — serve directly from 2/4/8-bit codes
// ---------------------------------------------------------------------------

// the packed step layout and the SIMD tiles below hard-code the panel width
const _: () = assert!(NR == 8, "packed panel layout assumes NR == 8");

/// Is packed-domain serving enabled? `CBQ_PACKED=0` (or `false`) forces
/// the old f32 pinning path — windows dequantized to f32 at materialize
/// time — mirroring the `CBQ_NAIVE_KERNELS` escape hatch. Anything else,
/// including unset, leaves packed serving on (it is bitwise-equal by
/// construction, so there is no accuracy reason to opt out).
pub fn packed_enabled() -> bool {
    use std::sync::OnceLock;
    static PACKED: OnceLock<bool> = OnceLock::new();
    *PACKED
        .get_or_init(|| !std::env::var("CBQ_PACKED").map(|v| v == "0" || v == "false").unwrap_or(false))
}

/// Quantized B-matrix panels: the packed-domain analogue of the f32 column
/// panels the blocked kernels build per call — except these are built once
/// at pin time from the snapshot's codes and reused by every forward, so
/// packed serving skips per-call repacking entirely.
///
/// Layout: `ceil(n / 8)` column panels; within panel `pj`, one *step* of
/// `8 * bits / 8 = bits` bytes per reduction index `p`, holding the 8
/// offset-binary codes `u = q + 2^(bits-1)` of columns `pj*8 .. pj*8+8`
/// packed LSB-first (tail columns padded with `q = 0`). `scales[j]` is the
/// per-output-channel dequant scale with the `EPS` floor already applied,
/// so the kernels' `w = (q as f32) * scales[j]` reproduces
/// `snapshot::lazy::dequant_codes` bit-for-bit — which is why [`qmatmul`]
/// is bitwise-equal to dequantize-then-[`matmul`].
#[derive(Debug, Clone, PartialEq)]
pub struct QPanels {
    k: usize,
    n: usize,
    bits: u8,
    scales: Vec<f32>,
    data: Vec<u8>,
}

impl QPanels {
    /// Bytes per reduction step: `NR` codes of `bits` bits. `NR == 8`
    /// keeps every step byte-aligned for all supported widths (1..=8).
    #[inline]
    fn step_bytes(bits: u8) -> usize {
        NR * bits as usize / 8
    }

    fn pack_impl(
        get: impl Fn(usize, usize) -> i32,
        k: usize,
        n: usize,
        bits: u8,
        s_w: &[f32],
    ) -> QPanels {
        assert!((1..=8).contains(&bits), "unsupported code width {bits}");
        assert_eq!(s_w.len(), n);
        let half = 1i32 << (bits - 1);
        let sb = Self::step_bytes(bits);
        let n_panels = n.div_ceil(NR);
        let mut data = vec![0u8; n_panels * k * sb];
        for pj in 0..n_panels {
            let j0 = pj * NR;
            let w = NR.min(n - j0);
            for p in 0..k {
                let step = &mut data[(pj * k + p) * sb..(pj * k + p + 1) * sb];
                for c in 0..NR {
                    let q = if c < w { get(p, j0 + c) } else { 0 };
                    assert!(
                        q >= -half && q < half,
                        "code {q} out of range for {bits}-bit grid"
                    );
                    let u = (q + half) as u32;
                    let bitpos = c * bits as usize;
                    step[bitpos >> 3] |= (u << (bitpos & 7)) as u8;
                    if (bitpos & 7) + bits as usize > 8 {
                        step[(bitpos >> 3) + 1] |= (u >> (8 - (bitpos & 7))) as u8;
                    }
                }
            }
        }
        let scales = s_w.iter().map(|&s| s.max(EPS)).collect();
        QPanels { k, n, bits, scales, data }
    }

    /// Pack row-major `[k, n]` signed codes (the CBQS weight layout:
    /// element `(p, j)` at `codes[p*n + j]`, per-column scales `s_w`) for
    /// [`qmatmul`]. Codes must lie on the signed `bits`-bit grid
    /// `[-2^(bits-1), 2^(bits-1))`.
    pub fn pack(codes: &[i32], k: usize, n: usize, bits: u8, s_w: &[f32]) -> QPanels {
        assert_eq!(codes.len(), k * n);
        Self::pack_impl(|p, j| codes[p * n + j], k, n, bits, s_w)
    }

    /// Pack transposed `[n, k]` signed codes (element `(p, j)` at
    /// `codes[j*k + p]`) — the B^T orientation [`matmul_transb`] consumes.
    /// The panel layout is orientation-free, so the result feeds the same
    /// [`qmatmul`] kernel.
    pub fn pack_transb(codes: &[i32], k: usize, n: usize, bits: u8, s_w: &[f32]) -> QPanels {
        assert_eq!(codes.len(), n * k);
        Self::pack_impl(|p, j| codes[j * k + p], k, n, bits, s_w)
    }

    /// Logical dequantized shape `[k, n]`.
    pub fn dims(&self) -> [usize; 2] {
        [self.k, self.n]
    }

    /// Reduction length (rows of the dequantized matrix).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output channels (columns of the dequantized matrix).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Code width in bits.
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Owned bytes of packed codes (panel padding included).
    pub fn code_bytes(&self) -> usize {
        self.data.len()
    }

    /// Owned bytes of the per-channel scale vector.
    pub fn scale_bytes(&self) -> usize {
        self.scales.len() * 4
    }

    /// Total owned heap bytes (codes + scales).
    pub fn heap_bytes(&self) -> usize {
        self.code_bytes() + self.scale_bytes()
    }

    /// Address of the code buffer — identity for resident-bytes dedup.
    pub fn codes_ptr(&self) -> usize {
        self.data.as_ptr() as usize
    }

    /// Address of the scale buffer — identity for resident-bytes dedup.
    pub fn scales_ptr(&self) -> usize {
        self.scales.as_ptr() as usize
    }

    /// Per-panel scale tile: the `EPS`-floored scales of columns
    /// `pj*NR..pj*NR+NR`, tail lanes padded with `0.0` (their products land
    /// in accumulator lanes that are never copied out).
    #[inline]
    fn panel_scales(&self, pj: usize) -> [f32; NR] {
        let j0 = pj * NR;
        let w = NR.min(self.n - j0);
        let mut psc = [0.0f32; NR];
        psc[..w].copy_from_slice(&self.scales[j0..j0 + w]);
        psc
    }

    /// Decode reduction step `p` of panel `pj` into `NR` dequantized
    /// weights: `wrow[c] = (q as f32) * psc[c]` — the exact
    /// `dequant_codes` arithmetic, evaluated in registers.
    #[inline]
    fn decode_step(&self, pj: usize, p: usize, psc: &[f32; NR], wrow: &mut [f32; NR]) {
        let sb = Self::step_bytes(self.bits);
        let base = (pj * self.k + p) * sb;
        let bytes = &self.data[base..base + sb];
        match self.bits {
            8 => {
                for c in 0..NR {
                    wrow[c] = (bytes[c] as i32 - 128) as f32 * psc[c];
                }
            }
            4 => {
                for c in 0..NR {
                    let u = (bytes[c >> 1] >> ((c & 1) * 4)) & 0xF;
                    wrow[c] = (u as i32 - 8) as f32 * psc[c];
                }
            }
            2 => {
                for c in 0..NR {
                    let u = (bytes[c >> 2] >> ((c & 3) * 2)) & 0x3;
                    wrow[c] = (u as i32 - 2) as f32 * psc[c];
                }
            }
            b => {
                let bits = b as usize;
                let half = 1i32 << (bits - 1);
                let mask = (1u32 << bits) - 1;
                for c in 0..NR {
                    let bitpos = c * bits;
                    let mut u = (bytes[bitpos >> 3] as u32) >> (bitpos & 7);
                    if (bitpos & 7) + bits > 8 {
                        u |= (bytes[(bitpos >> 3) + 1] as u32) << (8 - (bitpos & 7));
                    }
                    wrow[c] = ((u & mask) as i32 - half) as f32 * psc[c];
                }
            }
        }
    }

    /// Dequantize back to the row-major f32 `[k, n]` matrix the panels
    /// encode (`w[p][j] = q * scales[j]`) — the f32-pinning fallback and
    /// the oracle the bitwise-equality tests compare against.
    pub fn dequant(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.k * self.n];
        let n_panels = self.n.div_ceil(NR);
        let mut wrow = [0.0f32; NR];
        for pj in 0..n_panels {
            let j0 = pj * NR;
            let w = NR.min(self.n - j0);
            let psc = self.panel_scales(pj);
            for p in 0..self.k {
                self.decode_step(pj, p, &psc, &mut wrow);
                out[p * self.n + j0..p * self.n + j0 + w].copy_from_slice(&wrow[..w]);
            }
        }
        out
    }
}

/// Resident bytes a `[k, n]` x `bits` packed pin will own — panel code
/// bytes (including tail-panel padding) plus the f32 scale vector. Used by
/// `snapshot-info` / serve sizing without actually building the panels.
pub fn packed_resident_bytes(k: usize, n: usize, bits: u8) -> usize {
    n.div_ceil(NR) * k * (NR * bits as usize / 8) + n * 4
}

// ---------------------------------------------------------------------------
// runtime SIMD dispatch — one-time CPUID probe, CBQ_SIMD override
// ---------------------------------------------------------------------------

/// SIMD tier a packed-domain inner loop runs at. Every tier decodes the
/// codes to registers and performs the identical per-element mul-then-add
/// sequence (never fused), so tiers are bitwise-equal by construction —
/// the tier only changes how many lanes of that sequence run per
/// instruction. Ordered so [`Ord`] means "at most as wide as".
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdTier {
    /// Portable scalar loops — the only tier on non-x86_64 targets.
    Scalar,
    /// 128-bit SSE2 multiply-add tiles (baseline on x86_64); packed
    /// decode stays scalar.
    Sse2,
    /// 256-bit AVX2 tiles with in-register 2/4/8-bit code decode.
    Avx2,
}

impl SimdTier {
    /// Lower-case tier name as accepted by `CBQ_SIMD` and reported in
    /// bench/CLI output.
    pub fn name(self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Sse2 => "sse2",
            SimdTier::Avx2 => "avx2",
        }
    }
}

/// CPUID-probe the widest tier this CPU can run.
fn probe_simd() -> SimdTier {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            SimdTier::Avx2
        } else {
            SimdTier::Sse2 // baseline for the x86_64 target
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        SimdTier::Scalar
    }
}

/// Parse a `CBQ_SIMD` value: `Ok(None)` when unset/empty (auto-detect),
/// `Ok(Some(tier))` for a recognized tier name, `Err` otherwise. Pure so
/// it is unit-testable; mirrors `pool::parse_threads`.
fn parse_simd(raw: Option<&str>) -> Result<Option<SimdTier>, String> {
    let Some(raw) = raw else { return Ok(None) };
    let v = raw.trim().to_ascii_lowercase();
    if v.is_empty() {
        return Ok(None);
    }
    match v.as_str() {
        "scalar" => Ok(Some(SimdTier::Scalar)),
        "sse2" => Ok(Some(SimdTier::Sse2)),
        "avx2" => Ok(Some(SimdTier::Avx2)),
        _ => Err(format!(
            "CBQ_SIMD={raw}: expected scalar, sse2 or avx2 (unset the \
             variable to auto-detect; all tiers are bitwise-equal)"
        )),
    }
}

/// Validate `CBQ_SIMD` up front so a typo surfaces as a clean CLI error
/// instead of a panic inside the first packed matmul. Called from
/// `NativeBackend::new`, mirroring `pool::validate_threads`.
pub fn validate_simd() -> Result<(), String> {
    parse_simd(std::env::var("CBQ_SIMD").ok().as_deref()).map(|_| ())
}

/// Widest tier the running CPU supports (one-time probe, cached).
pub fn max_simd_tier() -> SimdTier {
    use std::sync::OnceLock;
    static MAX: OnceLock<SimdTier> = OnceLock::new();
    *MAX.get_or_init(probe_simd)
}

/// The tier the packed kernels dispatch to: `CBQ_SIMD` if set (clamped
/// down to what the CPU supports, with a one-time warning), else the
/// probed maximum. Resolved once per process.
pub fn simd_tier() -> SimdTier {
    use std::sync::OnceLock;
    static TIER: OnceLock<SimdTier> = OnceLock::new();
    *TIER.get_or_init(|| {
        let forced = match parse_simd(std::env::var("CBQ_SIMD").ok().as_deref()) {
            Ok(f) => f,
            Err(e) => panic!("{e}"),
        };
        let max = max_simd_tier();
        match forced {
            Some(t) if t > max => {
                eprintln!(
                    "warning: CBQ_SIMD={} exceeds this CPU's capability — using {}",
                    t.name(),
                    max.name()
                );
                max
            }
            Some(t) => t,
            None => max,
        }
    })
}

/// `acc[r] += avs[r] * wrow` for the first `rows` tile rows — IEEE
/// multiply then add per independent lane, never fused, so every SIMD
/// width and the scalar loop are bit-identical to each other and to the
/// f32 blocked micro-kernel's scalar loop.
#[inline]
fn madd_tile_scalar(acc: &mut [[f32; NR]; MR], rows: usize, avs: &[f32; MR], wrow: &[f32; NR]) {
    for (acc_row, &av) in acc.iter_mut().zip(avs).take(rows) {
        for (o, &wv) in acc_row.iter_mut().zip(wrow) {
            *o += av * wv;
        }
    }
}

/// SSE2 variant of [`madd_tile_scalar`] — two 128-bit halves per row,
/// same mul-then-add rounding sequence per lane.
#[cfg(target_arch = "x86_64")]
#[inline]
fn madd_tile_sse2(acc: &mut [[f32; NR]; MR], rows: usize, avs: &[f32; MR], wrow: &[f32; NR]) {
    // SSE2 is baseline on x86_64, so this needs no feature gate.
    unsafe {
        use std::arch::x86_64::*;
        let w0 = _mm_loadu_ps(wrow.as_ptr());
        let w1 = _mm_loadu_ps(wrow.as_ptr().add(4));
        for (acc_row, &av) in acc.iter_mut().zip(avs).take(rows) {
            let avv = _mm_set1_ps(av);
            let a0 = _mm_loadu_ps(acc_row.as_ptr());
            let a1 = _mm_loadu_ps(acc_row.as_ptr().add(4));
            _mm_storeu_ps(acc_row.as_mut_ptr(), _mm_add_ps(a0, _mm_mul_ps(avv, w0)));
            _mm_storeu_ps(acc_row.as_mut_ptr().add(4), _mm_add_ps(a1, _mm_mul_ps(avv, w1)));
        }
    }
}

/// One full `MR x NR` packed panel tile: decode every reduction step of
/// panel `pj` and accumulate into `acc` at the requested [`SimdTier`].
/// The per-element sequence — decode code `q`, `w = q as f32 * scale`,
/// `acc += a * w` with `p` ascending — is identical across tiers, so the
/// results are bitwise-equal (property-tested in `tests/proptests.rs`).
#[allow(clippy::too_many_arguments)]
#[inline]
fn q_panel_tile(
    q: &QPanels,
    pj: usize,
    psc: &[f32; NR],
    a: &[f32],
    a_stride: usize,
    row_base: usize,
    rows: usize,
    acc: &mut [[f32; NR]; MR],
    tier: SimdTier,
) {
    #[cfg(target_arch = "x86_64")]
    if tier == SimdTier::Avx2 && matches!(q.bits, 2 | 4 | 8) {
        // Safety: callers clamp `tier` to `max_simd_tier()`, so AVX2 is
        // available whenever this arm is reached.
        unsafe { q_panel_tile_avx2(q, pj, psc, a, a_stride, row_base, rows, acc) };
        return;
    }
    // Straddling bit widths (3/5/6/7) have no vector decode — they take
    // the scalar decode + SSE2/scalar madd path, which is bitwise-equal.
    let mut wrow = [0.0f32; NR];
    for p in 0..q.k {
        q.decode_step(pj, p, psc, &mut wrow);
        let mut avs = [0.0f32; MR];
        for (r, av) in avs.iter_mut().enumerate().take(rows) {
            *av = a[(row_base + r) * a_stride + p];
        }
        match tier {
            SimdTier::Scalar => madd_tile_scalar(acc, rows, &avs, &wrow),
            #[cfg(target_arch = "x86_64")]
            _ => madd_tile_sse2(acc, rows, &avs, &wrow),
            #[cfg(not(target_arch = "x86_64"))]
            _ => madd_tile_scalar(acc, rows, &avs, &wrow),
        }
    }
}

/// AVX2 panel tile: 2/4/8-bit codes are unpacked in-register (variable
/// shift + mask + offset-binary subtract), converted with exact
/// `i32 -> f32` conversions, scaled, then accumulated with one 256-bit
/// mul and one add per row — the same mul-then-add per-element sequence
/// as the scalar tile, hence bitwise-equal.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn q_panel_tile_avx2(
    q: &QPanels,
    pj: usize,
    psc: &[f32; NR],
    a: &[f32],
    a_stride: usize,
    row_base: usize,
    rows: usize,
    acc: &mut [[f32; NR]; MR],
) {
    use std::arch::x86_64::*;
    let sb = QPanels::step_bytes(q.bits);
    let base = pj * q.k * sb;
    let scv = _mm256_loadu_ps(psc.as_ptr());
    let mut accv = [_mm256_setzero_ps(); MR];
    for (r, av) in accv.iter_mut().enumerate().take(rows) {
        *av = _mm256_loadu_ps(acc[r].as_ptr());
    }
    for p in 0..q.k {
        let step = &q.data[base + p * sb..base + (p + 1) * sb];
        // Decode the 8 offset-binary codes of this step to i32 lanes.
        let qi = match q.bits {
            8 => {
                // sb == 8: one aligned-width load of exactly the step.
                let lo = _mm_loadl_epi64(step.as_ptr() as *const __m128i);
                _mm256_sub_epi32(_mm256_cvtepu8_epi32(lo), _mm256_set1_epi32(128))
            }
            4 => {
                // sb == 4: 8 nibbles in one u32, LSB-first.
                let word = u32::from_le_bytes([step[0], step[1], step[2], step[3]]);
                let v = _mm256_set1_epi32(word as i32);
                let sh = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
                let u = _mm256_and_si256(_mm256_srlv_epi32(v, sh), _mm256_set1_epi32(0xF));
                _mm256_sub_epi32(u, _mm256_set1_epi32(8))
            }
            _ => {
                // bits == 2, sb == 2: 8 crumbs in one u16, LSB-first.
                let word = u16::from_le_bytes([step[0], step[1]]) as u32;
                let v = _mm256_set1_epi32(word as i32);
                let sh = _mm256_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14);
                let u = _mm256_and_si256(_mm256_srlv_epi32(v, sh), _mm256_set1_epi32(0x3));
                _mm256_sub_epi32(u, _mm256_set1_epi32(2))
            }
        };
        let w = _mm256_mul_ps(_mm256_cvtepi32_ps(qi), scv);
        for (r, av) in accv.iter_mut().enumerate().take(rows) {
            let avv = _mm256_set1_ps(a[(row_base + r) * a_stride + p]);
            // mul then add, never fused — matches the scalar sequence.
            *av = _mm256_add_ps(*av, _mm256_mul_ps(avv, w));
        }
    }
    for (r, av) in accv.iter().enumerate().take(rows) {
        _mm256_storeu_ps(acc[r].as_mut_ptr(), *av);
    }
}

/// Packed-domain blocked micro-kernel: identical tiling, row chunking and
/// per-element accumulation order as the f32 `blocked_rows`, with the B
/// panel decoded to registers per reduction step instead of read from a
/// pre-dequantized buffer.
fn q_blocked_rows(
    out_chunk: &mut [f32],
    row0: usize,
    q: &QPanels,
    a: &[f32],
    a_stride: usize,
    tier: SimdTier,
) {
    let n = q.n;
    let rows_total = out_chunk.len() / n;
    let n_panels = n.div_ceil(NR);
    for ib in (0..rows_total).step_by(MR) {
        let rows = MR.min(rows_total - ib);
        for pj in 0..n_panels {
            let j0 = pj * NR;
            let w = NR.min(n - j0);
            let psc = q.panel_scales(pj);
            let mut acc = [[0.0f32; NR]; MR];
            q_panel_tile(q, pj, &psc, a, a_stride, row0 + ib, rows, &mut acc, tier);
            for (r, acc_row) in acc.iter().enumerate().take(rows) {
                let base = (ib + r) * n + j0;
                out_chunk[base..base + w].copy_from_slice(&acc_row[..w]);
            }
        }
    }
}

/// Run [`q_blocked_rows`] over `out`, splitting MR-aligned row chunks
/// across the worker pool with the same fixed chunking scheme (and the
/// same serial threshold) as the f32 `blocked_parallel`.
fn q_blocked_parallel(out: &mut [f32], q: &QPanels, a: &[f32], a_stride: usize, tier: SimdTier) {
    let n = q.n;
    let m = out.len() / n;
    let row_blocks = m.div_ceil(MR);
    let threads = num_threads().min(row_blocks.max(1));
    if threads <= 1 || 2 * m * q.k * n < 65_536 {
        q_blocked_rows(out, 0, q, a, a_stride, tier);
        return;
    }
    let per_rows = row_blocks.div_ceil(threads) * MR;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .chunks_mut(per_rows * n)
        .enumerate()
        .map(|(ti, chunk)| {
            Box::new(move || {
                q_blocked_rows(chunk, ti * per_rows, q, a, a_stride, tier);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool::run_scoped(tasks);
}

/// `A[m,k] @ dequant(Q)[k,n] -> [m,n]` computed directly from packed
/// codes: unpack-to-registers inside the cache-blocked panel loop, no f32
/// weight materialization. Bitwise-equal to `matmul(a, m, k, &q.dequant(),
/// n)` because the naive/blocked dispatch condition and both per-element
/// accumulation orders are replicated exactly (property-tested in
/// `tests/proptests.rs`).
pub fn qmatmul(a: &[f32], m: usize, k: usize, q: &QPanels) -> Vec<f32> {
    qmatmul_with_tier(a, m, k, q, simd_tier())
}

/// [`qmatmul`] at an explicit [`SimdTier`] (clamped to what the CPU
/// supports) — the entry point the bitwise-equality property tests use to
/// exercise every tier within one process, since [`simd_tier`] is
/// resolved once per process from `CBQ_SIMD`.
pub fn qmatmul_with_tier(a: &[f32], m: usize, k: usize, q: &QPanels, tier: SimdTier) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(q.k, k, "QPanels reduction length mismatch");
    let n = q.n;
    if force_naive() || m * k * n < BLOCK_MIN_MULS {
        return qmatmul_naive(a, m, k, q);
    }
    let tier = tier.min(max_simd_tier());
    let mut out = vec![0.0f32; m * n];
    q_blocked_parallel(&mut out, q, a, k, tier);
    out
}

/// [`qmatmul`] for panels packed from B^T codes ([`QPanels::pack_transb`]).
/// The panel layout is orientation-free, so this is the same kernel — kept
/// as a named entry point mirroring the f32 surface ([`matmul_transb`]).
pub fn qmatmul_transb(a: &[f32], m: usize, k: usize, q: &QPanels) -> Vec<f32> {
    qmatmul(a, m, k, q)
}

/// Single-row packed product `a[k] @ dequant(Q)[k,n] -> [n]` — the decode
/// hot path. Dispatch condition, panel tile and per-element accumulation
/// order are exactly [`qmatmul`] at `m == 1`, so
/// `qmatvec(a, k, q) == qmatmul(a, 1, k, q)` bitwise (property-tested);
/// what changes is the parallel split: with one output row there are no
/// row chunks to spread, so the blocked path splits *column panels*
/// across the pool instead — disjoint output ranges, per-element
/// reduction order untouched.
pub fn qmatvec(a: &[f32], k: usize, q: &QPanels) -> Vec<f32> {
    qmatvec_with_tier(a, k, q, simd_tier())
}

/// [`qmatvec`] at an explicit [`SimdTier`] (clamped to what the CPU
/// supports) — see [`qmatmul_with_tier`].
pub fn qmatvec_with_tier(a: &[f32], k: usize, q: &QPanels, tier: SimdTier) -> Vec<f32> {
    assert_eq!(a.len(), k);
    assert_eq!(q.k, k, "QPanels reduction length mismatch");
    let n = q.n;
    if force_naive() || k * n < BLOCK_MIN_MULS {
        return qmatmul_naive(a, 1, k, q);
    }
    let tier = tier.min(max_simd_tier());
    let mut out = vec![0.0f32; n];
    qmatvec_parallel(&mut out, q, a, tier);
    out
}

/// [`qmatvec`] for panels packed from B^T codes — same kernel, named
/// entry point mirroring [`qmatmul_transb`].
pub fn qmatvec_transb(a: &[f32], k: usize, q: &QPanels) -> Vec<f32> {
    qmatvec(a, k, q)
}

/// Split `out` into contiguous panel chunks across the worker pool (same
/// serial threshold as the matmul path at `m == 1`). Each chunk owns a
/// disjoint set of whole column panels, so parallelism never reorders any
/// element's reduction.
fn qmatvec_parallel(out: &mut [f32], q: &QPanels, a: &[f32], tier: SimdTier) {
    let n = q.n;
    let n_panels = n.div_ceil(NR);
    let threads = num_threads().min(n_panels.max(1));
    if threads <= 1 || 2 * q.k * n < 65_536 {
        qmatvec_panels(out, 0, q, a, tier);
        return;
    }
    let per = n_panels.div_ceil(threads);
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .chunks_mut(per * NR)
        .enumerate()
        .map(|(ti, chunk)| {
            Box::new(move || {
                qmatvec_panels(chunk, ti * per, q, a, tier);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool::run_scoped(tasks);
}

/// Accumulate the panels starting at `pj0` into `out_chunk` — one
/// [`q_panel_tile`] call per panel at `rows == 1`, identical to what
/// [`q_blocked_rows`] does for that panel of row 0.
fn qmatvec_panels(out_chunk: &mut [f32], pj0: usize, q: &QPanels, a: &[f32], tier: SimdTier) {
    let n = q.n;
    for (i, ochunk) in out_chunk.chunks_mut(NR).enumerate() {
        let pj = pj0 + i;
        let j0 = pj * NR;
        let w = NR.min(n - j0);
        let psc = q.panel_scales(pj);
        let mut acc = [[0.0f32; NR]; MR];
        q_panel_tile(q, pj, &psc, a, 1, 0, 1, &mut acc, tier);
        ochunk[..w].copy_from_slice(&acc[0][..w]);
    }
}

/// Row-parallel naive-order packed matmul: the same per-element
/// accumulation order (including the zero-A skip) as [`matmul_naive`] over
/// the dequantized matrix — the small-size / `CBQ_NAIVE_KERNELS` path.
pub fn qmatmul_naive(a: &[f32], m: usize, k: usize, q: &QPanels) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(q.k, k, "QPanels reduction length mismatch");
    let n = q.n;
    let n_panels = n.div_ceil(NR);
    let mut out = vec![0.0f32; m * n];
    par_rows(&mut out, n.max(1), 2 * k * n, |i, orow| {
        let arow = &a[i * k..(i + 1) * k];
        let mut wrow = [0.0f32; NR];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            for pj in 0..n_panels {
                let j0 = pj * NR;
                let w = NR.min(n - j0);
                let psc = q.panel_scales(pj);
                q.decode_step(pj, p, &psc, &mut wrow);
                for c in 0..w {
                    orow[j0 + c] += av * wrow[c];
                }
            }
        }
    });
    out
}

// ---------------------------------------------------------------------------
// rmsnorm
// ---------------------------------------------------------------------------

/// RMS-norm epsilon (matches python/compile/model.py).
pub const RMS_EPS: f32 = 1e-5;

/// `x: [rows, d]`, `g: [d]` -> normalized `[rows, d]`.
pub fn rmsnorm(x: &[f32], d: usize, g: &[f32]) -> Vec<f32> {
    assert_eq!(g.len(), d);
    let mut out = vec![0.0f32; x.len()];
    par_rows(&mut out, d, 4 * d, |i, orow| {
        let row = &x[i * d..(i + 1) * d];
        let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32 + RMS_EPS;
        let r = 1.0 / ms.sqrt();
        for ((o, &v), &gv) in orow.iter_mut().zip(row).zip(g) {
            *o = v * r * gv;
        }
    });
    out
}

/// Backward of [`rmsnorm`] (python/compile/ste.py `_rmsnorm_bwd`):
/// returns `dx`; when `dgamma` is given, accumulates `sum_rows gy * x * r`.
pub fn rmsnorm_bwd(
    x: &[f32],
    d: usize,
    g: &[f32],
    gy: &[f32],
    mut dgamma: Option<&mut [f32]>,
) -> Vec<f32> {
    assert_eq!(x.len(), gy.len());
    let rows = x.len() / d;
    let mut dx = vec![0.0f32; x.len()];
    // serial over rows when accumulating dgamma (shared accumulator);
    // row-parallel otherwise.
    let row_dx = |i: usize, out: &mut [f32]| -> f32 {
        let row = &x[i * d..(i + 1) * d];
        let gyr = &gy[i * d..(i + 1) * d];
        let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32 + RMS_EPS;
        let r = 1.0 / ms.sqrt();
        let mut mean_xgg = 0.0f32;
        for ((&v, &gv), &gyv) in row.iter().zip(g).zip(gyr) {
            mean_xgg += v * gyv * gv;
        }
        mean_xgg /= d as f32;
        for (j, o) in out.iter_mut().enumerate() {
            let gg = gyr[j] * g[j];
            *o = r * gg - row[j] * r * r * r * mean_xgg;
        }
        r
    };
    if let Some(dg) = dgamma.as_deref_mut() {
        assert_eq!(dg.len(), d);
        for i in 0..rows {
            let r = {
                let out = &mut dx[i * d..(i + 1) * d];
                row_dx(i, out)
            };
            let row = &x[i * d..(i + 1) * d];
            let gyr = &gy[i * d..(i + 1) * d];
            for ((dgj, &v), &gyv) in dg.iter_mut().zip(row).zip(gyr) {
                *dgj += gyv * v * r;
            }
        }
    } else {
        par_rows(&mut dx, d, 6 * d, |i, out| {
            row_dx(i, out);
        });
    }
    dx
}

// ---------------------------------------------------------------------------
// activation fake-quant (per-token dynamic, learnable clip alpha)
// ---------------------------------------------------------------------------

/// `x_eff = x + a_en * (fq(x) - x)` with per-row `s = max(alpha*max|x|/qmax,
/// EPS)` (kernels/ref.py `blend_act`).
pub fn blend_act(x: &[f32], k: usize, alpha: f32, qmax: f32, a_en: f32) -> Vec<f32> {
    if a_en == 0.0 {
        return x.to_vec();
    }
    let (lo, hi) = (-qmax - 1.0, qmax);
    let mut out = vec![0.0f32; x.len()];
    par_rows(&mut out, k, 6 * k, |i, orow| {
        let row = &x[i * k..(i + 1) * k];
        let m = row.iter().fold(0.0f32, |a, v| a.max(v.abs()));
        let s = (alpha * m / qmax).max(EPS);
        for (o, &v) in orow.iter_mut().zip(row) {
            let q = (v / s).round().clamp(lo, hi);
            *o = v + a_en * (q * s - v);
        }
    });
    out
}

/// Backward of [`blend_act`] given `dxe` (grad wrt `x_eff`): returns
/// `(dx, dalpha)` per ste.py `_qmatmul_bwd`'s activation-side rules.
pub fn blend_act_bwd(
    x: &[f32],
    k: usize,
    alpha: f32,
    qmax: f32,
    a_en: f32,
    dxe: &[f32],
) -> (Vec<f32>, f32) {
    if a_en == 0.0 {
        return (dxe.to_vec(), 0.0);
    }
    assert_eq!(x.len(), dxe.len());
    let rows = x.len() / k;
    let (lo, hi) = (-qmax - 1.0, qmax);
    let mut dx = vec![0.0f32; x.len()];
    let mut dalpha = 0.0f32;
    for i in 0..rows {
        let row = &x[i * k..(i + 1) * k];
        let grow = &dxe[i * k..(i + 1) * k];
        let m = row.iter().fold(0.0f32, |a, v| a.max(v.abs()));
        let s = (alpha * m / qmax).max(EPS);
        let mut ds_tok = 0.0f32;
        for (j, (&v, &g)) in row.iter().zip(grow).enumerate() {
            let vv = v / s;
            let r = vv.round();
            let in_range = r >= lo && r <= hi;
            let rc = r.clamp(lo, hi);
            let z = if in_range { 1.0 } else { 0.0 };
            dx[i * k + j] = g * (1.0 - a_en + a_en * z);
            let dq_ds = if in_range { rc - vv } else { rc };
            ds_tok += g * a_en * dq_ds;
        }
        dalpha += ds_tok * m / qmax;
    }
    (dx, dalpha)
}

// ---------------------------------------------------------------------------
// weight fake-quant (per-output-channel scale, AdaRound offset rho)
// ---------------------------------------------------------------------------

/// `w_hat = w + w_en * (fq(w) - w)` with `fq = clip(floor(w/s)+rho, lo, hi)
/// * s`, `s = max(s_w, EPS)` per output channel (column). `rho = None`
/// means nearest rounding.
pub fn blend_weight(
    w: &[f32],
    k: usize,
    n: usize,
    s_w: &[f32],
    rho: Option<&[f32]>,
    qmax: f32,
    w_en: f32,
) -> Vec<f32> {
    assert_eq!(w.len(), k * n);
    assert_eq!(s_w.len(), n);
    if w_en == 0.0 {
        return w.to_vec();
    }
    let (lo, hi) = (-qmax - 1.0, qmax);
    let mut out = vec![0.0f32; w.len()];
    par_rows(&mut out, n, 6 * n, |i, orow| {
        let row = &w[i * n..(i + 1) * n];
        for (j, (o, &v)) in orow.iter_mut().zip(row).enumerate() {
            let s = s_w[j].max(EPS);
            let vv = v / s;
            let r = match rho {
                Some(rh) => rh[i * n + j],
                None => {
                    if vv - vv.floor() >= 0.5 {
                        1.0
                    } else {
                        0.0
                    }
                }
            };
            let q = (vv.floor() + r).clamp(lo, hi);
            *o = v + w_en * (q * s - v);
        }
    });
    out
}

/// Gradients of [`blend_weight`] given `g` (grad wrt `w_hat`), per ste.py
/// `_qweight_bwd` (STE + per-channel LSQ). The weight matrix itself is not
/// learnable in the `win_grad_*` graphs, so `dw` (the STE pass-through
/// `g * (1 - w_en + w_en*z)`) is deliberately not materialized.
pub struct WeightGrads {
    /// Per-output-channel LSQ gradient wrt the step sizes, `[n]`.
    pub ds_w: Vec<f32>,
    /// Gradient wrt the rounding offset rho, `[k*n]`.
    pub drho: Vec<f32>,
}

/// Backward of [`blend_weight`]: see [`WeightGrads`].
pub fn blend_weight_bwd(
    w: &[f32],
    k: usize,
    n: usize,
    s_w: &[f32],
    rho: Option<&[f32]>,
    qmax: f32,
    w_en: f32,
    g: &[f32],
) -> WeightGrads {
    assert_eq!(w.len(), g.len());
    let mut ds_w = vec![0.0f32; n];
    let mut drho = vec![0.0f32; k * n];
    if w_en == 0.0 {
        return WeightGrads { ds_w, drho };
    }
    let (lo, hi) = (-qmax - 1.0, qmax);
    for i in 0..k {
        for j in 0..n {
            let s = s_w[j].max(EPS);
            let v = w[i * n + j] / s;
            let r = match rho {
                Some(rh) => rh[i * n + j],
                None => {
                    if v - v.floor() >= 0.5 {
                        1.0
                    } else {
                        0.0
                    }
                }
            };
            let q_unc = v.floor() + r;
            let in_range = q_unc >= lo && q_unc <= hi;
            let q = q_unc.clamp(lo, hi);
            let gv = g[i * n + j];
            let z = if in_range { 1.0 } else { 0.0 };
            let dq_ds = if in_range { q - v } else { q };
            ds_w[j] += gv * w_en * dq_ds;
            drho[i * n + j] = gv * w_en * s * z;
        }
    }
    WeightGrads { ds_w, drho }
}

// ---------------------------------------------------------------------------
// rectified sigmoid (AdaRound Eq. 8) + derivative
// ---------------------------------------------------------------------------

/// d rect_sigmoid / dv: zero where the pre-clip value left [0, 1].
pub fn rect_sigmoid_d(v: f32) -> f32 {
    let sig = 1.0 / (1.0 + (-v).exp());
    let pre = sig * (ZETA - GAMMA) + GAMMA;
    if !(0.0..=1.0).contains(&pre) {
        return 0.0;
    }
    sig * (1.0 - sig) * (ZETA - GAMMA)
}

/// rho = rect_sigmoid(v0 + delta) elementwise; returns (v_pre, rho).
pub fn rho_soft(v0: &[f32], delta: &[f32]) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(v0.len(), delta.len());
    let v_pre: Vec<f32> = v0.iter().zip(delta).map(|(&a, &b)| a + b).collect();
    let rho = v_pre.iter().map(|&v| rect_sigmoid(v)).collect();
    (v_pre, rho)
}

/// Nearest-rounding offset (kernels/ref.py `round_ste_rho`).
pub fn rho_hard(w: &[f32], n: usize, s_w: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; w.len()];
    for (idx, (&v, o)) in w.iter().zip(out.iter_mut()).enumerate() {
        let s = s_w[idx % n].max(EPS);
        let vv = v / s;
        *o = if vv - vv.floor() >= 0.5 { 1.0 } else { 0.0 };
    }
    out
}

// ---------------------------------------------------------------------------
// softmax / silu
// ---------------------------------------------------------------------------

/// In-place row softmax over the last `d` elements of each row.
pub fn softmax_rows(x: &mut [f32], d: usize) {
    for row in x.chunks_mut(d) {
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Row log-softmax: returns a new buffer.
pub fn log_softmax_rows(x: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    par_rows(&mut out, d, 6 * d, |i, orow| {
        let row = &x[i * d..(i + 1) * d];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let lse = row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln() + m;
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = v - lse;
        }
    });
    out
}

/// SiLU activation `x * sigmoid(x)`.
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Derivative of [`silu`].
pub fn silu_d(x: f32) -> f32 {
    let sig = 1.0 / (1.0 + (-x).exp());
    sig * (1.0 + x * (1.0 - sig))
}

// ---------------------------------------------------------------------------
// causal RoPE attention
// ---------------------------------------------------------------------------

/// Per-(batch, head) backward cache.
pub struct HeadCache {
    /// RoPE-rotated query, `[s, hd]`.
    pub q_r: Vec<f32>,
    /// RoPE-rotated key, `[s, hd]`.
    pub k_r: Vec<f32>,
    /// raw values, `[s, hd]`.
    pub v_h: Vec<f32>,
    /// softmax probabilities, `[s, s]` (zero above the diagonal).
    pub probs: Vec<f32>,
}

/// Causal multi-head attention with RoPE (python/compile/model.py
/// `attention`). Inputs/outputs are `[b, s, h*hd]`.
pub struct Attention {
    /// Batch rows.
    pub b: usize,
    /// Sequence length.
    pub s: usize,
    /// Head count.
    pub h: usize,
    /// Per-head width.
    pub hd: usize,
    /// `[s, hd/2]` RoPE tables.
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl Attention {
    /// Precompute the RoPE tables for a `(batch, seq, heads, head_dim)`
    /// shape; `head_dim` must be even.
    pub fn new(b: usize, s: usize, h: usize, hd: usize) -> Self {
        assert!(hd % 2 == 0, "head_dim must be even for RoPE");
        let half = hd / 2;
        let mut cos = vec![0.0f32; s * half];
        let mut sin = vec![0.0f32; s * half];
        for pos in 0..s {
            for p in 0..half {
                let freq = (10000.0f64).powf(-2.0 * p as f64 / hd as f64);
                let ang = pos as f64 * freq;
                cos[pos * half + p] = ang.cos() as f32;
                sin[pos * half + p] = ang.sin() as f32;
            }
        }
        Self { b, s, h, hd, cos, sin }
    }

    /// Gather head `hh` of `x [b,s,h*hd]` for batch `bb` into `[s, hd]`.
    fn gather(&self, x: &[f32], bb: usize, hh: usize) -> Vec<f32> {
        let d = self.h * self.hd;
        let mut out = vec![0.0f32; self.s * self.hd];
        for ss in 0..self.s {
            let src = (bb * self.s + ss) * d + hh * self.hd;
            out[ss * self.hd..(ss + 1) * self.hd].copy_from_slice(&x[src..src + self.hd]);
        }
        out
    }

    fn scatter(&self, out: &mut [f32], bb: usize, hh: usize, head: &[f32]) {
        let d = self.h * self.hd;
        for ss in 0..self.s {
            let dst = (bb * self.s + ss) * d + hh * self.hd;
            out[dst..dst + self.hd].copy_from_slice(&head[ss * self.hd..(ss + 1) * self.hd]);
        }
    }

    /// Apply RoPE in place to `[s, hd]` (interleaved even/odd pairs).
    fn rope(&self, x: &mut [f32], inverse: bool) {
        let half = self.hd / 2;
        for ss in 0..self.s {
            for p in 0..half {
                let c = self.cos[ss * half + p];
                let sn = if inverse { -self.sin[ss * half + p] } else { self.sin[ss * half + p] };
                let i0 = ss * self.hd + 2 * p;
                let (x1, x2) = (x[i0], x[i0 + 1]);
                x[i0] = x1 * c - x2 * sn;
                x[i0 + 1] = x1 * sn + x2 * c;
            }
        }
    }

    /// Forward. Returns `(context [b,s,h*hd], per-(b,h) caches)`; caches are
    /// empty when `want_cache` is false.
    pub fn forward(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        want_cache: bool,
    ) -> (Vec<f32>, Vec<HeadCache>) {
        let (s, hd) = (self.s, self.hd);
        let inv_sqrt = 1.0 / (hd as f32).sqrt();
        let n_bh = self.b * self.h;
        // each (batch, head) item is independent: scoped-thread map
        let per_head = par_map(n_bh, 1, |bh| {
            let (bb, hh) = (bh / self.h, bh % self.h);
            let mut q_r = self.gather(q, bb, hh);
            let mut k_r = self.gather(k, bb, hh);
            let v_h = self.gather(v, bb, hh);
            self.rope(&mut q_r, false);
            self.rope(&mut k_r, false);
            let mut probs = vec![0.0f32; s * s];
            for sq in 0..s {
                let qrow = &q_r[sq * hd..(sq + 1) * hd];
                let mut m = f32::NEG_INFINITY;
                for sk in 0..=sq {
                    let krow = &k_r[sk * hd..(sk + 1) * hd];
                    let mut dot = 0.0f32;
                    for (&a, &b) in qrow.iter().zip(krow) {
                        dot += a * b;
                    }
                    let sc = dot * inv_sqrt;
                    probs[sq * s + sk] = sc;
                    m = m.max(sc);
                }
                let mut sum = 0.0f32;
                for sk in 0..=sq {
                    let e = (probs[sq * s + sk] - m).exp();
                    probs[sq * s + sk] = e;
                    sum += e;
                }
                let inv = 1.0 / sum;
                for sk in 0..=sq {
                    probs[sq * s + sk] *= inv;
                }
            }
            let mut ctx = vec![0.0f32; s * hd];
            for sq in 0..s {
                let crow = &mut ctx[sq * hd..(sq + 1) * hd];
                for sk in 0..=sq {
                    let p = probs[sq * s + sk];
                    if p == 0.0 {
                        continue;
                    }
                    let vrow = &v_h[sk * hd..(sk + 1) * hd];
                    for (c, &vv) in crow.iter_mut().zip(vrow) {
                        *c += p * vv;
                    }
                }
            }
            (ctx, HeadCache { q_r, k_r, v_h, probs })
        });
        let d = self.h * self.hd;
        let mut out = vec![0.0f32; self.b * s * d];
        let mut caches = Vec::with_capacity(if want_cache { n_bh } else { 0 });
        for (bh, (ctx, cache)) in per_head.into_iter().enumerate() {
            self.scatter(&mut out, bh / self.h, bh % self.h, &ctx);
            if want_cache {
                caches.push(cache);
            }
        }
        (out, caches)
    }

    /// Backward: `dout [b,s,h*hd]` -> `(dq, dk, dv)` (grads wrt the
    /// *pre-RoPE* q/k and raw v).
    pub fn backward(&self, caches: &[HeadCache], dout: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (s, hd) = (self.s, self.hd);
        let inv_sqrt = 1.0 / (hd as f32).sqrt();
        let n_bh = self.b * self.h;
        assert_eq!(caches.len(), n_bh);
        let per_head = par_map(n_bh, 1, |bh| {
            let cache = &caches[bh];
            let dctx = self.gather(dout, bh / self.h, bh % self.h);
            let mut dv = vec![0.0f32; s * hd];
            let mut dq_r = vec![0.0f32; s * hd];
            let mut dk_r = vec![0.0f32; s * hd];
            let mut dscores = vec![0.0f32; s * s];
            for sq in 0..s {
                let drow = &dctx[sq * hd..(sq + 1) * hd];
                // dprobs and the softmax-row reduction
                let mut dp = vec![0.0f32; sq + 1];
                let mut dot_pp = 0.0f32;
                for (sk, dpv) in dp.iter_mut().enumerate() {
                    let vrow = &cache.v_h[sk * hd..(sk + 1) * hd];
                    let mut acc = 0.0f32;
                    for (&a, &b) in drow.iter().zip(vrow) {
                        acc += a * b;
                    }
                    *dpv = acc;
                    dot_pp += cache.probs[sq * s + sk] * acc;
                }
                for (sk, &dpv) in dp.iter().enumerate() {
                    let p = cache.probs[sq * s + sk];
                    dscores[sq * s + sk] = p * (dpv - dot_pp);
                    // dv accumulation
                    let dvrow = &mut dv[sk * hd..(sk + 1) * hd];
                    for (o, &g) in dvrow.iter_mut().zip(drow) {
                        *o += p * g;
                    }
                }
            }
            for sq in 0..s {
                let dqrow_start = sq * hd;
                for sk in 0..=sq {
                    let ds = dscores[sq * s + sk] * inv_sqrt;
                    if ds == 0.0 {
                        continue;
                    }
                    let krow = &cache.k_r[sk * hd..(sk + 1) * hd];
                    let qrow = &cache.q_r[sq * hd..(sq + 1) * hd];
                    for e in 0..hd {
                        dq_r[dqrow_start + e] += ds * krow[e];
                        dk_r[sk * hd + e] += ds * qrow[e];
                    }
                }
            }
            // un-rotate: RoPE backward is the inverse rotation
            self.rope(&mut dq_r, true);
            self.rope(&mut dk_r, true);
            (dq_r, dk_r, dv)
        });
        let d = self.h * self.hd;
        let mut dq = vec![0.0f32; self.b * s * d];
        let mut dk = vec![0.0f32; self.b * s * d];
        let mut dv = vec![0.0f32; self.b * s * d];
        for (bh, (dq_h, dk_h, dv_h)) in per_head.into_iter().enumerate() {
            let (bb, hh) = (bh / self.h, bh % self.h);
            self.scatter(&mut dq, bb, hh, &dq_h);
            self.scatter(&mut dk, bb, hh, &dk_h);
            self.scatter(&mut dv, bb, hh, &dv_h);
        }
        (dq, dk, dv)
    }

    /// Apply RoPE in place to one `[h*hd]` position at absolute position
    /// `pos`, per head — the single-position counterpart of [`rope`](Self::rope)
    /// (same tables, same f32 expressions, so the rotated values are
    /// bitwise-identical to the full-sequence path).
    fn rope_one(&self, x: &mut [f32], pos: usize) {
        debug_assert_eq!(x.len(), self.h * self.hd);
        let half = self.hd / 2;
        for hh in 0..self.h {
            for p in 0..half {
                let c = self.cos[pos * half + p];
                let sn = self.sin[pos * half + p];
                let i0 = hh * self.hd + 2 * p;
                let (x1, x2) = (x[i0], x[i0 + 1]);
                x[i0] = x1 * c - x2 * sn;
                x[i0 + 1] = x1 * sn + x2 * c;
            }
        }
    }

    /// Incremental single-position attention against a [`KvCache`]: rotate
    /// and append this position's key (values are stored raw), then attend
    /// the rotated query over the cached prefix.
    ///
    /// `q`/`k`/`v` are one position of one sequence, `[h*hd]`; the position
    /// is `cache.len()` (the cache *is* the position counter) and must stay
    /// below the `seq` this table was built for. The score/softmax/context
    /// loops replicate [`forward`](Self::forward)'s per-`(sq, sk)` operation
    /// order exactly — running max over scores in `sk` order, `exp` and sum
    /// in `sk` order, one `1/sum` multiply, context accumulation in `sk`
    /// order with the same `p == 0.0` skip — so decode at position `p` is
    /// bitwise-equal to row `p` of a full prefill over the same prefix.
    pub fn attend_one(&self, q: &[f32], k: &[f32], v: &[f32], cache: &mut KvCache) -> Vec<f32> {
        let (h, hd) = (self.h, self.hd);
        let d = h * hd;
        assert_eq!(q.len(), d, "attend_one q must be one [h*hd] position");
        assert_eq!(k.len(), d);
        assert_eq!(v.len(), d);
        assert_eq!(cache.h, h, "cache head count mismatch");
        assert_eq!(cache.hd, hd, "cache head width mismatch");
        let pos = cache.len();
        assert!(
            pos < self.s,
            "KV cache full: position {pos} but RoPE tables cover seq {}",
            self.s
        );
        let inv_sqrt = 1.0 / (hd as f32).sqrt();
        let mut q_r = q.to_vec();
        let mut k_r = k.to_vec();
        self.rope_one(&mut q_r, pos);
        self.rope_one(&mut k_r, pos);
        cache.k_r.extend_from_slice(&k_r);
        cache.v.extend_from_slice(v);
        cache.len += 1;
        let mut out = vec![0.0f32; d];
        let mut probs = vec![0.0f32; pos + 1];
        for hh in 0..h {
            let qrow = &q_r[hh * hd..(hh + 1) * hd];
            let mut m = f32::NEG_INFINITY;
            for (sk, pr) in probs.iter_mut().enumerate() {
                let krow = &cache.k_r[sk * d + hh * hd..sk * d + (hh + 1) * hd];
                let mut dot = 0.0f32;
                for (&a, &b) in qrow.iter().zip(krow) {
                    dot += a * b;
                }
                let sc = dot * inv_sqrt;
                *pr = sc;
                m = m.max(sc);
            }
            let mut sum = 0.0f32;
            for pr in probs.iter_mut() {
                let e = (*pr - m).exp();
                *pr = e;
                sum += e;
            }
            let inv = 1.0 / sum;
            for pr in probs.iter_mut() {
                *pr *= inv;
            }
            let crow = &mut out[hh * hd..(hh + 1) * hd];
            for (sk, &p) in probs.iter().enumerate() {
                if p == 0.0 {
                    continue;
                }
                let vrow = &cache.v[sk * d + hh * hd..sk * d + (hh + 1) * hd];
                for (c, &vv) in crow.iter_mut().zip(vrow) {
                    *c += p * vv;
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// inference KV cache
// ---------------------------------------------------------------------------

/// Inference-shaped KV cache for one (sequence, block) pair: RoPE-rotated
/// keys and raw values appended one position at a time, each stored as
/// `[len, h*hd]` row slabs. Unlike [`HeadCache`] (the backward-pass cache,
/// which holds probabilities for gradient replay), this holds exactly what
/// incremental decode re-reads: rotated K (rotation depends only on the
/// absolute position, so it never needs recomputing) and raw V.
pub struct KvCache {
    h: usize,
    hd: usize,
    /// rotated keys, `[len, h*hd]`
    k_r: Vec<f32>,
    /// raw values, `[len, h*hd]`
    v: Vec<f32>,
    /// positions cached so far
    len: usize,
}

impl KvCache {
    /// Empty cache for a model with `h` heads of width `hd`.
    pub fn new(h: usize, hd: usize) -> Self {
        Self { h, hd, k_r: Vec::new(), v: Vec::new(), len: 0 }
    }

    /// Positions appended so far — also the absolute position the *next*
    /// [`Attention::attend_one`] call will occupy.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Has nothing been appended yet?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Heap bytes held by the cached K/V slabs.
    pub fn heap_bytes(&self) -> u64 {
        4 * (self.k_r.capacity() + self.v.capacity()) as u64
    }
}

/// Per-sequence KV state across every transformer block of a model: one
/// [`KvCache`] per block, all advancing in lockstep as the sequence
/// decodes. This is the unit `Backend::decode_step` threads through the
/// pinned window executables (`kv[row].blocks[absolute_block_index]`).
/// The `Default` value has zero blocks — a placeholder for `mem::take`,
/// not a usable cache.
#[derive(Default)]
pub struct SeqKv {
    /// One cache per block, indexed by absolute block (layer) number.
    pub blocks: Vec<KvCache>,
}

impl SeqKv {
    /// Fresh caches for an `n_layers`-block model with `h` heads of width
    /// `hd`.
    pub fn new(n_layers: usize, h: usize, hd: usize) -> Self {
        Self { blocks: (0..n_layers).map(|_| KvCache::new(h, hd)).collect() }
    }

    /// Positions decoded so far (every block advances in lockstep; this
    /// reads the first).
    pub fn len(&self) -> usize {
        self.blocks.first().map_or(0, |c| c.len())
    }

    /// Has nothing been decoded yet?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Heap bytes across all blocks' cached K/V slabs.
    pub fn heap_bytes(&self) -> u64 {
        self.blocks.iter().map(|c| c.heap_bytes()).sum()
    }
}

// ---------------------------------------------------------------------------
// losses
// ---------------------------------------------------------------------------

/// Reconstruction loss (Eq. 7): `l2_w * mse + kld_w * kld` with the KLD
/// taken over softmax of the hidden dimension. Returns (loss, mse, kld).
pub fn recon_loss(h: &[f32], target: &[f32], d: usize, l2_w: f32, kld_w: f32) -> (f32, f32, f32) {
    assert_eq!(h.len(), target.len());
    let n = h.len();
    let rows = n / d;
    let mut mse = 0.0f64;
    for (&a, &b) in h.iter().zip(target) {
        let diff = (a - b) as f64;
        mse += diff * diff;
    }
    let mse = (mse / n as f64) as f32;
    let logp = log_softmax_rows(target, d);
    let logq = log_softmax_rows(h, d);
    let mut kld = 0.0f64;
    for i in 0..rows {
        let mut row = 0.0f64;
        for j in 0..d {
            let lp = logp[i * d + j] as f64;
            let lq = logq[i * d + j] as f64;
            row += lp.exp() * (lp - lq);
        }
        kld += row;
    }
    let kld = (kld / rows as f64) as f32;
    (l2_w * mse + kld_w * kld, mse, kld)
}

/// d(recon_loss)/dh.
pub fn recon_loss_bwd(h: &[f32], target: &[f32], d: usize, l2_w: f32, kld_w: f32) -> Vec<f32> {
    let n = h.len();
    let rows = n / d;
    let logp = log_softmax_rows(target, d);
    let logq = log_softmax_rows(h, d);
    let mut dh = vec![0.0f32; n];
    let inv_n = 1.0 / n as f32;
    let inv_rows = 1.0 / rows as f32;
    for i in 0..n {
        let p = logp[i].exp();
        let q = logq[i].exp();
        dh[i] = l2_w * 2.0 * (h[i] - target[i]) * inv_n + kld_w * (q - p) * inv_rows;
    }
    dh
}

/// Rounding-commitment regularizer for one linear:
/// `mean(1 - |2 rho - 1|^beta)` (Eq. 12, mean-normalized as in
/// model.com_loss). When `drho` is given, *adds* `scale * d/drho`.
pub fn com_loss(rho: &[f32], beta: f32, scale: f32, drho: Option<&mut [f32]>) -> f32 {
    let n = rho.len();
    let inv_n = 1.0 / n as f32;
    let mut total = 0.0f64;
    for &r in rho {
        let u = (2.0 * r - 1.0).abs();
        total += (1.0 - u.powf(beta)) as f64;
    }
    if let Some(d) = drho {
        assert_eq!(d.len(), n);
        for (o, &r) in d.iter_mut().zip(rho) {
            let u = 2.0 * r - 1.0;
            let au = u.abs();
            if au > 0.0 {
                *o += scale * (-2.0 * beta * au.powf(beta - 1.0) * u.signum()) * inv_n;
            }
        }
    }
    (total * inv_n as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_tensor_matmul() {
        let a: Vec<f32> = (0..6).map(|v| v as f32 * 0.5 - 1.0).collect();
        let b: Vec<f32> = (0..12).map(|v| (v as f32).sin()).collect();
        let got = matmul(&a, 2, 3, &b, 4);
        let ta = crate::tensor::Tensor::new(vec![2, 3], a.clone());
        let tb = crate::tensor::Tensor::new(vec![3, 4], b.clone());
        let want = ta.matmul(&tb);
        for (x, y) in got.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn blocked_matmuls_match_naive_bitwise() {
        // the blocked kernels keep the naive per-element accumulation order
        // (reduction index ascending, one accumulator per element), so on
        // finite inputs they must agree bit-for-bit — including inputs with
        // planted zeros (the naive loops skip zero A-elements)
        let mut seed = 0x9E3779B97F4A7C15u64;
        let mut next = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            seed
        };
        for trial in 0..40 {
            let m = 1 + (next() % 19) as usize;
            let k = 1 + (next() % 33) as usize;
            let n = 1 + (next() % 21) as usize;
            let mut mk_vec = |len: usize, zeros: bool| -> Vec<f32> {
                (0..len)
                    .map(|_| {
                        let r = next();
                        if zeros && r % 4 == 0 {
                            0.0
                        } else {
                            ((r >> 40) as f32 / (1u64 << 24) as f32 - 0.5) * 2.0
                        }
                    })
                    .collect()
            };
            let zeros = trial % 2 == 0;
            let a = mk_vec(m * k, zeros);
            let b = mk_vec(k * n, false);
            // force the blocked path regardless of size thresholds
            let panels = pack_panels(|p, j| b[p * n + j], k, n);
            let mut got = vec![0.0f32; m * n];
            blocked_rows(&mut got, n, 0, k, &panels, &a, k, false);
            assert_eq!(got, matmul_naive(&a, m, k, &b, n), "matmul trial {trial} ({m}x{k}x{n})");

            let bt = mk_vec(n * k, false);
            let panels = pack_panels(|p, j| bt[j * k + p], k, n);
            let mut got = vec![0.0f32; m * n];
            blocked_rows(&mut got, n, 0, k, &panels, &a, k, false);
            assert_eq!(
                got,
                matmul_transb_naive(&a, m, k, &bt, n),
                "transb trial {trial} ({m}x{k}x{n})"
            );

            let bm = mk_vec(m * n, false);
            let panels = pack_panels(|p, j| bm[p * n + j], m, n);
            let mut got = vec![0.0f32; k * n];
            blocked_rows(&mut got, n, 0, m, &panels, &a, k, true);
            assert_eq!(
                got,
                matmul_transa_naive(&a, m, k, &bm, n),
                "transa trial {trial} ({m}x{k}x{n})"
            );
        }
    }

    #[test]
    fn public_matmuls_match_naive_above_block_threshold() {
        // sizes past BLOCK_MIN_MULS exercise the packed/parallel path
        let (m, k, n) = (33, 40, 37);
        let a: Vec<f32> = (0..m * k).map(|i| ((i as f32) * 0.137).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| ((i as f32) * 0.211).cos()).collect();
        assert_eq!(matmul(&a, m, k, &b, n), matmul_naive(&a, m, k, &b, n));
        let bt: Vec<f32> = (0..n * k).map(|i| ((i as f32) * 0.173).sin()).collect();
        assert_eq!(matmul_transb(&a, m, k, &bt, n), matmul_transb_naive(&a, m, k, &bt, n));
        let bm: Vec<f32> = (0..m * n).map(|i| ((i as f32) * 0.119).cos()).collect();
        assert_eq!(matmul_transa(&a, m, k, &bm, n), matmul_transa_naive(&a, m, k, &bm, n));
    }

    /// Reference dequantization: the exact `snapshot::lazy::dequant_codes`
    /// arithmetic, written out independently of `QPanels::dequant`.
    fn dequant_ref(codes: &[i32], k: usize, n: usize, s_w: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; k * n];
        for p in 0..k {
            for j in 0..n {
                out[p * n + j] = codes[p * n + j] as f32 * s_w[j].max(EPS);
            }
        }
        out
    }

    #[test]
    fn qmatmul_matches_dequant_matmul_bitwise() {
        // random small shapes x bit widths x edge scales (exact zero ->
        // EPS floor, negative -> EPS floor, tiny, huge); A gets planted
        // zeros to exercise the naive path's zero-skip
        let mut seed = 0xD1B54A32D192ED03u64;
        let mut next = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            seed
        };
        for &bits in &[2u8, 4, 8] {
            let half = 1i64 << (bits - 1);
            for trial in 0..10 {
                let m = 1 + (next() % 11) as usize;
                let k = 1 + (next() % 29) as usize;
                let n = 1 + (next() % 19) as usize;
                let codes: Vec<i32> =
                    (0..k * n).map(|_| ((next() % (2 * half) as u64) as i64 - half) as i32).collect();
                let s_w: Vec<f32> = (0..n)
                    .map(|_| match next() % 5 {
                        0 => 0.0,
                        1 => -1.5,
                        2 => EPS / 3.0,
                        3 => 3.7e4,
                        _ => (next() >> 40) as f32 / (1u64 << 24) as f32 + 1e-3,
                    })
                    .collect();
                let a: Vec<f32> = (0..m * k)
                    .map(|_| {
                        let r = next();
                        if r % 4 == 0 {
                            0.0
                        } else {
                            ((r >> 40) as f32 / (1u64 << 24) as f32 - 0.5) * 2.0
                        }
                    })
                    .collect();
                let q = QPanels::pack(&codes, k, n, bits, &s_w);
                let deq = dequant_ref(&codes, k, n, &s_w);
                assert_eq!(q.dequant(), deq, "dequant bits={bits} trial={trial}");
                assert_eq!(
                    qmatmul(&a, m, k, &q),
                    matmul(&a, m, k, &deq, n),
                    "qmatmul bits={bits} trial={trial} ({m}x{k}x{n})"
                );
                // force both the blocked and naive-order internals at this
                // size regardless of the dispatch thresholds
                let mut blocked = vec![0.0f32; m * n];
                q_blocked_parallel(&mut blocked, &q, &a, k, simd_tier());
                let panels = pack_panels(|p, j| deq[p * n + j], k, n);
                let mut fblocked = vec![0.0f32; m * n];
                blocked_rows(&mut fblocked, n, 0, k, &panels, &a, k, false);
                assert_eq!(blocked, fblocked, "blocked bits={bits} trial={trial}");
                assert_eq!(
                    qmatmul_naive(&a, m, k, &q),
                    matmul_naive(&a, m, k, &deq, n),
                    "naive bits={bits} trial={trial}"
                );

                // B^T orientation: [n, k] codes, same per-column scales
                let codes_t: Vec<i32> =
                    (0..n * k).map(|_| ((next() % (2 * half) as u64) as i64 - half) as i32).collect();
                let qt = QPanels::pack_transb(&codes_t, k, n, bits, &s_w);
                let mut deq_t = vec![0.0f32; k * n];
                for p in 0..k {
                    for j in 0..n {
                        deq_t[p * n + j] = codes_t[j * k + p] as f32 * s_w[j].max(EPS);
                    }
                }
                assert_eq!(
                    qmatmul_transb(&a, m, k, &qt),
                    matmul(&a, m, k, &deq_t, n),
                    "transb bits={bits} trial={trial}"
                );
            }
        }
    }

    #[test]
    fn qmatmul_blocked_and_parallel_path_matches() {
        // past BLOCK_MIN_MULS and the parallel threshold: exercises the
        // pool-split blocked packed kernel against the f32 blocked kernel
        let (m, k, n) = (33, 40, 37);
        let codes: Vec<i32> = (0..k * n).map(|i| (i % 16) as i32 - 8).collect();
        let mut s_w: Vec<f32> = (0..n).map(|j| 0.02 + (j as f32) * 1e-3).collect();
        s_w[0] = 0.0; // EPS-floored channel
        let a: Vec<f32> = (0..m * k).map(|i| ((i as f32) * 0.137).sin()).collect();
        let q = QPanels::pack(&codes, k, n, 4, &s_w);
        let deq = dequant_ref(&codes, k, n, &s_w);
        assert_eq!(qmatmul(&a, m, k, &q), matmul(&a, m, k, &deq, n));
    }

    #[test]
    fn parse_simd_accepts_tiers_and_rejects_typos() {
        assert_eq!(parse_simd(None), Ok(None));
        assert_eq!(parse_simd(Some("")), Ok(None));
        assert_eq!(parse_simd(Some("  ")), Ok(None));
        assert_eq!(parse_simd(Some("scalar")), Ok(Some(SimdTier::Scalar)));
        assert_eq!(parse_simd(Some("SSE2")), Ok(Some(SimdTier::Sse2)));
        assert_eq!(parse_simd(Some(" avx2 ")), Ok(Some(SimdTier::Avx2)));
        let err = parse_simd(Some("avx512")).unwrap_err();
        assert!(err.contains("CBQ_SIMD=avx512"), "{err}");
        assert!(err.contains("scalar, sse2 or avx2"), "{err}");
        // tiers are ordered by width so clamping is a min()
        assert!(SimdTier::Scalar < SimdTier::Sse2 && SimdTier::Sse2 < SimdTier::Avx2);
        assert!(validate_simd().is_ok() || std::env::var("CBQ_SIMD").is_ok());
    }

    #[test]
    fn qmatvec_matches_qmatmul_row_every_tier() {
        // one blocked-path size and one naive-path size, every tier the
        // CPU supports (wider requests clamp down), against both the
        // dequant oracle and the corresponding qmatmul row
        for &(k, n) in &[(96usize, 80usize), (9, 7)] {
            let codes: Vec<i32> = (0..k * n).map(|i| (i % 16) as i32 - 8).collect();
            let mut s_w: Vec<f32> = (0..n).map(|j| 0.02 + (j as f32) * 1e-3).collect();
            s_w[0] = 0.0; // EPS-floored channel
            let mut a: Vec<f32> = (0..k).map(|i| ((i as f32) * 0.137).sin()).collect();
            a[3] = 0.0; // naive zero-skip
            let q = QPanels::pack(&codes, k, n, 4, &s_w);
            let deq = dequant_ref(&codes, k, n, &s_w);
            let oracle = matmul(&a, 1, k, &deq, n);
            for tier in [SimdTier::Scalar, SimdTier::Sse2, SimdTier::Avx2] {
                assert_eq!(
                    qmatvec_with_tier(&a, k, &q, tier),
                    oracle,
                    "qmatvec {}x{} tier={}",
                    k,
                    n,
                    tier.name()
                );
                assert_eq!(
                    qmatvec_with_tier(&a, k, &q, tier),
                    qmatmul_with_tier(&a, 1, k, &q, tier),
                    "qmatvec vs qmatmul row {}x{} tier={}",
                    k,
                    n,
                    tier.name()
                );
            }
            assert_eq!(qmatvec(&a, k, &q), oracle);
            assert_eq!(qmatvec_transb(&a, k, &q), qmatmul_transb(&a, 1, k, &q));
        }
    }

    #[test]
    fn qpanels_accounting_and_edges() {
        // 4-bit 7-column matrix: one panel, tail-padded; accounting covers
        // padding and scales
        let codes: Vec<i32> = (0..3 * 7).map(|i| (i % 16) as i32 - 8).collect();
        let s_w = vec![0.1f32; 7];
        let q = QPanels::pack(&codes, 3, 7, 4, &s_w);
        assert_eq!(q.dims(), [3, 7]);
        assert_eq!(q.bits(), 4);
        assert_eq!(q.code_bytes(), 3 * 4); // 1 panel x 3 steps x 4 bytes
        assert_eq!(q.scale_bytes(), 7 * 4);
        assert_eq!(q.heap_bytes(), packed_resident_bytes(3, 7, 4));
        // full-range codes survive the round trip at every width
        for &bits in &[2u8, 4, 8] {
            let half = 1i32 << (bits - 1);
            let codes: Vec<i32> = (-half..half).collect();
            let k = codes.len();
            let q = QPanels::pack(&codes, k, 1, bits, &[1.0]);
            let deq = q.dequant();
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(deq[i], c as f32, "bits={bits} code={c}");
            }
        }
    }

    #[test]
    fn transposed_matmuls_consistent() {
        let a: Vec<f32> = (0..8).map(|v| (v as f32 * 0.37).cos()).collect(); // [2,4]
        let b: Vec<f32> = (0..6).map(|v| (v as f32 * 0.11).sin()).collect(); // [2,3]
        // a^T @ b = [4,3]
        let got = matmul_transa(&a, 2, 4, &b, 3);
        let ta = crate::tensor::Tensor::new(vec![2, 4], a.clone()).transpose2();
        let tb = crate::tensor::Tensor::new(vec![2, 3], b.clone());
        let want = ta.matmul(&tb);
        for (x, y) in got.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-6);
        }
        // a [2,4] @ (b' [3,4])^T = [2,3]
        let b2: Vec<f32> = (0..12).map(|v| (v as f32 * 0.21).cos()).collect();
        let got2 = matmul_transb(&a, 2, 4, &b2, 3);
        let tb2 = crate::tensor::Tensor::new(vec![3, 4], b2).transpose2();
        let want2 = crate::tensor::Tensor::new(vec![2, 4], a).matmul(&tb2);
        for (x, y) in got2.iter().zip(&want2.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn rmsnorm_matches_reference() {
        let x = vec![1.0f32, -2.0, 3.0, 0.5, 0.0, -1.5];
        let g = vec![1.0f32, 0.5, 2.0];
        let y = rmsnorm(&x, 3, &g);
        for i in 0..2 {
            let row = &x[i * 3..(i + 1) * 3];
            let ms = row.iter().map(|v| v * v).sum::<f32>() / 3.0 + RMS_EPS;
            let r = 1.0 / ms.sqrt();
            for j in 0..3 {
                assert!((y[i * 3 + j] - row[j] * r * g[j]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn rmsnorm_bwd_finite_difference() {
        // rmsnorm is smooth: FD must match the analytic backward closely
        let x = vec![0.3f32, -0.7, 1.1, 0.2, -0.1, 0.9, 0.4, -0.5];
        let d = 4;
        let g = vec![1.0f32, 0.8, 1.2, 0.9];
        let gy = vec![0.5f32, -0.2, 0.1, 0.7, -0.3, 0.4, 0.2, -0.6];
        let dx = rmsnorm_bwd(&x, d, &g, &gy, None);
        let loss = |xs: &[f32]| -> f32 {
            rmsnorm(xs, d, &g).iter().zip(&gy).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-3;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (loss(&xp) - loss(&xm)) / (2.0 * eps);
            assert!(
                (fd - dx[i]).abs() < 2e-3,
                "rmsnorm dx[{i}]: fd {fd} vs analytic {}",
                dx[i]
            );
        }
    }

    #[test]
    fn blend_act_disabled_is_identity() {
        let x = vec![0.1f32, -0.2, 0.3];
        assert_eq!(blend_act(&x, 3, 1.0, 7.0, 0.0), x);
        let (dx, da) = blend_act_bwd(&x, 3, 1.0, 7.0, 0.0, &[1.0, 1.0, 1.0]);
        assert_eq!(dx, vec![1.0, 1.0, 1.0]);
        assert_eq!(da, 0.0);
    }

    #[test]
    fn blend_act_matches_host_quant() {
        let x = vec![0.11f32, -0.52, 0.93, -0.04, 0.7, 0.2, -0.9, 0.45];
        let t = crate::tensor::Tensor::new(vec![2, 4], x.clone());
        let want = crate::quant::fake_quant_act(&t, 0.9, 7.0);
        let got = blend_act(&x, 4, 0.9, 7.0, 1.0);
        for (a, b) in got.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn blend_weight_nearest_matches_rtn() {
        let w: Vec<f32> = (0..12).map(|v| ((v * 7 % 5) as f32 - 2.0) * 0.13).collect();
        let tw = crate::tensor::Tensor::new(vec![4, 3], w.clone());
        let s = crate::quant::init_scales(&tw, 7.0);
        let want = crate::quant::fake_quant_rtn(&tw, &s, 7.0);
        let got = blend_weight(&w, 4, 3, &s.data, None, 7.0, 1.0);
        for (a, b) in got.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn attention_is_causal_and_deterministic() {
        let (b, s, h, hd) = (2usize, 5usize, 2usize, 4usize);
        let d = h * hd;
        let n = b * s * d;
        let mk = |seed: u32| -> Vec<f32> {
            (0..n).map(|i| ((i as f32 + seed as f32) * 0.7).sin() * 0.3).collect()
        };
        let attn = Attention::new(b, s, h, hd);
        let (q, k, v) = (mk(1), mk(2), mk(3));
        let (o1, _) = attn.forward(&q, &k, &v, false);
        let (o2, _) = attn.forward(&q, &k, &v, true);
        assert_eq!(o1, o2, "attention must be deterministic");
        // causality: position 0 output depends only on position 0 inputs
        let mut v2 = v.clone();
        for bb in 0..b {
            // mutate the last position's values only
            let base = (bb * s + (s - 1)) * d;
            for e in 0..d {
                v2[base + e] += 1.0;
            }
        }
        let (o3, _) = attn.forward(&q, &k, &v2, false);
        for bb in 0..b {
            for ss in 0..s - 1 {
                let base = (bb * s + ss) * d;
                for e in 0..d {
                    assert_eq!(o1[base + e], o3[base + e], "future leaked into position {ss}");
                }
            }
        }
    }

    #[test]
    fn attention_backward_finite_difference() {
        // attention is smooth: directional FD must match <dout, dq/dk/dv>
        let (b, s, h, hd) = (1usize, 4usize, 1usize, 4usize);
        let d = h * hd;
        let n = b * s * d;
        let mk = |seed: u32| -> Vec<f32> {
            (0..n).map(|i| ((i as f32 * 1.3 + seed as f32) * 0.9).sin() * 0.5).collect()
        };
        let attn = Attention::new(b, s, h, hd);
        let (q, k, v) = (mk(1), mk(2), mk(3));
        let dout = mk(4);
        let (_, caches) = attn.forward(&q, &k, &v, true);
        let (dq, dk, dv) = attn.backward(&caches, &dout);
        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f64 {
            let (o, _) = attn.forward(q, k, v, false);
            o.iter().zip(&dout).map(|(a, b)| (a * b) as f64).sum()
        };
        let eps = 1e-3f32;
        let dir = mk(9);
        for (buf, grad, which) in [(&q, &dq, "q"), (&k, &dk, "k"), (&v, &dv, "v")] {
            let plus: Vec<f32> = buf.iter().zip(&dir).map(|(&a, &b)| a + eps * b).collect();
            let minus: Vec<f32> = buf.iter().zip(&dir).map(|(&a, &b)| a - eps * b).collect();
            let (lp, lm) = match which {
                "q" => (loss(&plus, &k, &v), loss(&minus, &k, &v)),
                "k" => (loss(&q, &plus, &v), loss(&q, &minus, &v)),
                _ => (loss(&q, &k, &plus), loss(&q, &k, &minus)),
            };
            let fd = (lp - lm) / (2.0 * eps as f64);
            let analytic: f64 = grad.iter().zip(&dir).map(|(&a, &b)| (a * b) as f64).sum();
            assert!(
                (fd - analytic).abs() < 1e-2 * (1.0 + analytic.abs()),
                "d{which}: fd {fd} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn recon_loss_bwd_finite_difference() {
        let d = 4;
        let h: Vec<f32> = (0..8).map(|i| (i as f32 * 0.61).sin()).collect();
        let t: Vec<f32> = (0..8).map(|i| (i as f32 * 0.43).cos()).collect();
        let (l0, _, _) = recon_loss(&h, &t, d, 1.0, 1.0);
        assert!(l0.is_finite());
        let dh = recon_loss_bwd(&h, &t, d, 1.0, 1.0);
        let eps = 1e-3;
        for i in 0..h.len() {
            let mut hp = h.clone();
            hp[i] += eps;
            let mut hm = h.clone();
            hm[i] -= eps;
            let (lp, _, _) = recon_loss(&hp, &t, d, 1.0, 1.0);
            let (lm, _, _) = recon_loss(&hm, &t, d, 1.0, 1.0);
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - dh[i]).abs() < 2e-3, "dh[{i}]: fd {fd} vs {}", dh[i]);
        }
    }

    #[test]
    fn com_loss_value_and_grad() {
        let rho = vec![0.5f32, 0.9, 0.1, 0.7];
        let mut drho = vec![0.0f32; 4];
        let c = com_loss(&rho, 2.0, 1.0, Some(&mut drho));
        // mean(1 - (2r-1)^2) = 1 - mean([0, .64, .64, .16]) = 1 - 0.36
        assert!((c - 0.64).abs() < 1e-6, "{c}");
        // d/drho at 0.5 is 0; at 0.9 it is -2*2*0.8/4 = -0.8
        assert_eq!(drho[0], 0.0);
        assert!((drho[1] + 0.8).abs() < 1e-6, "{}", drho[1]);
        assert!((drho[2] - 0.8).abs() < 1e-6, "{}", drho[2]);
    }

    #[test]
    fn log_softmax_rows_normalized() {
        let x = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        let ls = log_softmax_rows(&x, 3);
        for row in ls.chunks(3) {
            let sum: f32 = row.iter().map(|v| v.exp()).sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }
}
