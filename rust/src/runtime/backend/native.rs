//! Native CPU execution backend: interprets the manifest's executable
//! *semantics* directly on the host, so the whole CBQ pipeline (quantize,
//! eval, export, serve, hessian probes) runs without compiled HLO
//! artifacts or a PJRT plugin.
//!
//! The executable families are dispatched by name (the same names aot.py
//! exports — `win_fwd_w{K}_{cfg}`, `win_grad_w{K}_{cfg}`,
//! `win_grad_dense_w{K}_{cfg}`, `capture_{cfg}`, `lm_eval_{cfg}`), with the
//! manifest's `ModelCfg` supplying shapes and the bindings supplying every
//! tensor — the backend itself is stateless between calls, exactly like
//! the PJRT path, so `pin` simply retains host tensors. Gradients
//! implement the STE/LSQ rules documented in python/compile/ste.py (see
//! `backend/kernels.rs`).
//!
//! Parallelism: matmuls are cache-blocked and split across batch rows,
//! attention across (batch, head) pairs — all on the persistent worker
//! pool (`backend::pool`), bit-deterministic. The backend itself is
//! `Send + Sync` (stats and the RoPE cache sit behind mutexes), so the
//! serve layer can execute independent window batches concurrently
//! against one backend instance.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::kernels::{self, Attention, HeadCache, KvCache, SeqKv};
use super::{check_shape, lock_or_recover, Backend, ExecKind, Pinned, PinnedInner, RuntimeStats};
use crate::quant::LINEARS;
use crate::runtime::manifest::{Manifest, ModelCfg};
use crate::runtime::{Artifacts, Value};
use crate::tensor::Tensor;

/// Which intermediate feeds each linear's capture (model.CAPTURE_SOURCES).
fn capture_source(linear: &str) -> &'static str {
    match linear {
        "wq" | "wk" | "wv" => "attn_in",
        "wo" => "attn_mix",
        "wgate" | "wup" => "mlp_in",
        "wdown" => "mlp_act",
        other => panic!("unknown linear {other}"),
    }
}

// ---------------------------------------------------------------------------
// name-bound input views
// ---------------------------------------------------------------------------

struct In<'a> {
    map: &'a BTreeMap<&'a str, &'a Value>,
    exec: &'a str,
}

impl<'a> In<'a> {
    fn value(&self, name: &str) -> Result<&'a Value> {
        self.map
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("missing input `{name}` for executable {}", self.exec))
    }

    fn f32(&self, name: &str) -> Result<&'a Tensor> {
        match self.value(name)? {
            Value::F32(t) => Ok(t),
            _ => Err(anyhow!("input `{name}` of {}: expected f32", self.exec)),
        }
    }

    /// Like [`In::f32`] but a missing binding is `None` instead of an
    /// error — for inputs the packed serving path legitimately omits
    /// (s_w / v0 / LoRA factors / target).
    fn opt_f32(&self, name: &str) -> Result<Option<&'a Tensor>> {
        match self.map.get(name).copied() {
            None => Ok(None),
            Some(Value::F32(t)) => Ok(Some(t)),
            Some(_) => Err(anyhow!("input `{name}` of {}: expected f32", self.exec)),
        }
    }

    fn i32(&self, name: &str) -> Result<&'a crate::tensor::TensorI32> {
        match self.value(name)? {
            Value::I32(t) => Ok(t),
            _ => Err(anyhow!("input `{name}` of {}: expected i32", self.exec)),
        }
    }

    /// A linear's weight operand: dense f32 or packed-domain codes.
    fn weight(&self, name: &str) -> Result<WeightRef<'a>> {
        match self.value(name)? {
            Value::F32(t) => Ok(WeightRef::Dense(t)),
            Value::Packed(p) => Ok(WeightRef::Packed(p.panels().as_ref())),
            Value::I32(_) => {
                Err(anyhow!("input `{name}` of {}: expected f32 or packed weight", self.exec))
            }
        }
    }

    fn scalar(&self, name: &str) -> Result<f32> {
        let t = self.f32(name)?;
        ensure!(!t.data.is_empty(), "input `{name}` of {}: empty scalar", self.exec);
        Ok(t.data[0])
    }
}

/// One linear's weight operand: the dense f32 matrix, or pre-panelized
/// quantized codes ([`kernels::QPanels`]) the quantized matmul consumes
/// directly. Packed weights carry the deployment-frozen rounding baked
/// into the codes, so only the inference path (`w_en == 0`, no gradients)
/// accepts them.
#[derive(Clone, Copy)]
enum WeightRef<'a> {
    Dense(&'a Tensor),
    Packed(&'a kernels::QPanels),
}

struct Glob {
    use_lora: f32,
    beta: f32,
    gamma_c: f32,
    l2_w: f32,
    kld_w: f32,
}

impl Glob {
    fn parse(inp: &In) -> Result<Self> {
        Ok(Self {
            use_lora: inp.scalar("globals.use_lora")?,
            beta: inp.scalar("globals.beta")?,
            gamma_c: inp.scalar("globals.gamma_c")?,
            l2_w: inp.scalar("globals.l2_w")?,
            kld_w: inp.scalar("globals.kld_w")?,
        })
    }
}

struct BlockRef<'a> {
    attn_norm: &'a Tensor,
    mlp_norm: &'a Tensor,
    linears: BTreeMap<&'static str, WeightRef<'a>>,
}

impl<'a> BlockRef<'a> {
    fn parse(inp: &In<'a>, j: usize) -> Result<Self> {
        let mut linears = BTreeMap::new();
        for l in LINEARS {
            linears.insert(l, inp.weight(&format!("blocks.{j}.{l}"))?);
        }
        Ok(Self {
            attn_norm: inp.f32(&format!("blocks.{j}.attn_norm"))?,
            mlp_norm: inp.f32(&format!("blocks.{j}.mlp_norm"))?,
            linears,
        })
    }

    fn lin(&self, l: &str) -> WeightRef<'a> {
        self.linears[l]
    }
}

/// Quantization parameters of one linear, as bound by
/// `Pipeline::bind_qblock` (dense mode carries `v` instead of `a1`/`a2`).
/// `s_w`, `v0` and the LoRA factors are optional because the packed
/// serving path never binds them (the scale lives inside the packed
/// panels, the rounding is baked into the codes); the soft-rounding /
/// gradient paths that need them error cleanly when they are absent.
struct QLinRef<'a> {
    s_w: Option<&'a Tensor>,
    alpha: f32,
    a1: Option<&'a Tensor>,
    a2: Option<&'a Tensor>,
    v_dense: Option<&'a Tensor>,
    v0: Option<&'a Tensor>,
    qmax_w: f32,
    qmax_a: f32,
    w_en: f32,
    a_en: f32,
}

struct QBlockRef<'a> {
    lin: BTreeMap<&'static str, QLinRef<'a>>,
}

impl<'a> QBlockRef<'a> {
    fn parse(inp: &In<'a>, j: usize, dense: bool) -> Result<Self> {
        let mut lin = BTreeMap::new();
        for l in LINEARS {
            let p = format!("qblocks.{j}.{l}");
            let (a1, a2, v_dense) = if dense {
                (None, None, inp.opt_f32(&format!("{p}.v"))?)
            } else {
                (inp.opt_f32(&format!("{p}.a1"))?, inp.opt_f32(&format!("{p}.a2"))?, None)
            };
            lin.insert(
                l,
                QLinRef {
                    s_w: inp.opt_f32(&format!("{p}.s_w"))?,
                    alpha: inp.scalar(&format!("{p}.alpha"))?,
                    a1,
                    a2,
                    v_dense,
                    v0: inp.opt_f32(&format!("{p}.v0"))?,
                    qmax_w: inp.scalar(&format!("{p}.qmax_w"))?,
                    qmax_a: inp.scalar(&format!("{p}.qmax_a"))?,
                    w_en: inp.scalar(&format!("{p}.w_en"))?,
                    a_en: inp.scalar(&format!("{p}.a_en"))?,
                },
            );
        }
        Ok(Self { lin })
    }

    fn get(&self, l: &str) -> &QLinRef<'a> {
        &self.lin[l]
    }
}

// ---------------------------------------------------------------------------
// fake-quantized linear: forward (+ cache) and backward
// ---------------------------------------------------------------------------

struct QlCache {
    /// raw input `[rows, k]`
    x: Vec<f32>,
    /// activation-fake-quantized input
    x_eff: Vec<f32>,
    /// weight-fake-quantized matrix `[k, n]`
    w_hat: Vec<f32>,
    /// the rho actually used in the forward blend (None when w_en == 0)
    rho_blend: Option<Vec<f32>>,
    /// soft-rho pre-sigmoid (v0 + delta) and soft rho, for the LoRA/dense
    /// gradient path and the commitment regularizer
    v_pre: Option<Vec<f32>>,
    rho_soft: Option<Vec<f32>>,
}

/// `y = blend_act(x) @ blend_weight(w)` with the rounding offset
/// `rho = use_lora * h(v0 + delta) + (1 - use_lora) * nearest`.
///
/// A packed weight operand takes the packed-domain fast path: the weight
/// blend is identity at `w_en == 0` and the codes already encode the
/// exported rounding, so `y = qmatmul(blend_act(x), codes)` — bitwise-equal
/// to dequantizing and running the f32 kernel, with no f32 weight ever
/// materialized (and no per-call panel repacking).
fn qlinear_fwd(
    x: &[f32],
    rows: usize,
    w: WeightRef,
    q: &QLinRef,
    use_lora: f32,
    grad: bool,
) -> Result<(Vec<f32>, Option<QlCache>)> {
    let wt = match w {
        WeightRef::Packed(p) => {
            ensure!(
                q.w_en == 0.0 && !grad,
                "packed weights serve the frozen deployment graph only \
                 (w_en = 0, no gradients) — set CBQ_PACKED=0 for the f32 path"
            );
            let k = p.k();
            debug_assert_eq!(x.len(), rows * k);
            let x_eff = kernels::blend_act(x, k, q.alpha, q.qmax_a, q.a_en);
            // single-row products (the decode_step hot path) take the
            // matvec kernel — bitwise-equal to qmatmul at rows == 1
            let y = if rows == 1 {
                kernels::qmatvec(&x_eff, k, p)
            } else {
                kernels::qmatmul(&x_eff, rows, k, p)
            };
            return Ok((y, None));
        }
        WeightRef::Dense(t) => t,
    };
    let (k, n) = (wt.rows(), wt.cols());
    debug_assert_eq!(x.len(), rows * k);
    if grad {
        ensure!(q.s_w.is_some(), "quantized linear missing s_w (required for gradients)");
    }
    let need_soft = grad || (use_lora > 0.0 && q.w_en != 0.0);
    let (v_pre, rho_soft) = if need_soft {
        let v0 = q
            .v0
            .ok_or_else(|| anyhow!("quantized linear missing v0 (soft-rounding path)"))?;
        let delta = match (q.a1, q.a2, q.v_dense) {
            (Some(a1), Some(a2), _) => kernels::matmul(&a1.data, k, a1.cols(), &a2.data, n),
            (_, _, Some(v)) => v.data.to_vec(),
            _ => bail!("quantized linear missing LoRA factors (a1/a2 or v) for the soft-rounding path"),
        };
        let (vp, rs) = kernels::rho_soft(&v0.data, &delta);
        (Some(vp), Some(rs))
    } else {
        (None, None)
    };
    let rho_blend: Option<Vec<f32>> = if q.w_en != 0.0 {
        let s_w = q
            .s_w
            .ok_or_else(|| anyhow!("quantized linear missing s_w (required when w_en != 0)"))?;
        if use_lora >= 1.0 {
            rho_soft.clone()
        } else {
            let hard = kernels::rho_hard(&wt.data, n, &s_w.data);
            if use_lora <= 0.0 {
                Some(hard)
            } else {
                let rs = rho_soft.as_ref().expect("soft rho computed when use_lora > 0");
                Some(
                    rs.iter()
                        .zip(&hard)
                        .map(|(&s, &h)| use_lora * s + (1.0 - use_lora) * h)
                        .collect(),
                )
            }
        }
    } else {
        None
    };
    let w_hat = if q.w_en == 0.0 {
        // identity blend: bitwise the same as blend_weight at w_en == 0,
        // without requiring the (possibly unbound) s_w
        wt.data.to_vec()
    } else {
        let s_w = q.s_w.expect("s_w presence verified computing rho_blend");
        kernels::blend_weight(&wt.data, k, n, &s_w.data, rho_blend.as_deref(), q.qmax_w, q.w_en)
    };
    let x_eff = kernels::blend_act(x, k, q.alpha, q.qmax_a, q.a_en);
    let y = kernels::matmul(&x_eff, rows, k, &w_hat, n);
    let cache = if grad {
        Some(QlCache { x: x.to_vec(), x_eff, w_hat, rho_blend, v_pre, rho_soft })
    } else {
        None
    };
    Ok((y, cache))
}

/// Gradients of one quantized linear wrt its learnables.
struct LinGrads {
    ds_w: Tensor,
    dalpha: f32,
    da1: Option<Tensor>,
    da2: Option<Tensor>,
    dv: Option<Tensor>,
}

/// Backward through `qlinear_fwd` given `g = dL/dy`. Adds this linear's
/// commitment-loss value to `com_total` and folds `gamma_c * dcom/drho`
/// into the LoRA/dense gradient path. Returns `dL/dx`.
#[allow(clippy::too_many_arguments)]
fn qlinear_bwd(
    g: &[f32],
    rows: usize,
    w: WeightRef,
    q: &QLinRef,
    cache: &QlCache,
    use_lora: f32,
    beta: f32,
    gamma_c: f32,
    com_total: &mut f32,
) -> (Vec<f32>, LinGrads) {
    let w = match w {
        WeightRef::Dense(t) => t,
        // qlinear_fwd rejects packed weights under grad, so a grad cache
        // can only exist for a dense weight
        WeightRef::Packed(_) => unreachable!("gradients never run on packed weights"),
    };
    let s_w = q.s_w.expect("s_w presence verified in the grad forward");
    let (k, n) = (w.rows(), w.cols());
    debug_assert_eq!(g.len(), rows * n);
    // matmul backward
    let dxe = kernels::matmul_transb(g, rows, n, &cache.w_hat, k);
    let dw_hat = kernels::matmul_transa(&cache.x_eff, rows, k, g, n);
    // activation side: STE + LSQ-into-alpha
    let (dx, dalpha) = kernels::blend_act_bwd(&cache.x, k, q.alpha, q.qmax_a, q.a_en, &dxe);
    // weight side: LSQ for s_w, drho for the rounding offset
    let wg = kernels::blend_weight_bwd(
        &w.data,
        k,
        n,
        &s_w.data,
        cache.rho_blend.as_deref(),
        q.qmax_w,
        q.w_en,
        &dw_hat,
    );
    // rho chain: the reconstruction path reaches the soft rho through the
    // `use_lora` blend (the hard branch is stop-gradient); the commitment
    // regularizer always reads the soft rho.
    let rho_soft = cache.rho_soft.as_ref().expect("grad cache holds soft rho");
    let v_pre = cache.v_pre.as_ref().expect("grad cache holds v_pre");
    let mut drho_soft: Vec<f32> = wg.drho.iter().map(|&v| v * use_lora).collect();
    *com_total += kernels::com_loss(rho_soft, beta, gamma_c, Some(&mut drho_soft));
    let dv: Vec<f32> = drho_soft
        .iter()
        .zip(v_pre)
        .map(|(&dr, &vp)| dr * kernels::rect_sigmoid_d(vp))
        .collect();
    let (da1, da2, dv_dense) = match (q.a1, q.a2, q.v_dense) {
        (Some(a1), Some(a2), _) => {
            let r = a1.cols();
            // da1 = dv @ a2^T  [k, r];  da2 = a1^T @ dv  [r, n]
            let da1 = kernels::matmul_transb(&dv, k, n, &a2.data, r);
            let da2 = kernels::matmul_transa(&a1.data, k, r, &dv, n);
            (
                Some(Tensor::new(vec![k, r], da1)),
                Some(Tensor::new(vec![r, n], da2)),
                None,
            )
        }
        (_, _, Some(_)) => (None, None, Some(Tensor::new(vec![k, n], dv))),
        _ => unreachable!(),
    };
    (
        dx,
        LinGrads {
            ds_w: Tensor::new(vec![n], wg.ds_w),
            dalpha,
            da1,
            da2,
            dv: dv_dense,
        },
    )
}

// ---------------------------------------------------------------------------
// per-block cache
// ---------------------------------------------------------------------------

struct BlockCache {
    h_in: Vec<f32>,
    h_mid: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    heads: Vec<HeadCache>,
    ql: BTreeMap<&'static str, QlCache>,
}

// ---------------------------------------------------------------------------
// the backend
// ---------------------------------------------------------------------------

/// The native CPU execution backend: interprets the manifest's executable
/// semantics directly on the host (see the module docs).
pub struct NativeBackend {
    manifest: Manifest,
    stats: Mutex<RuntimeStats>,
    /// RoPE-table cache keyed by (batch, seq, heads, head_dim).
    attn: Mutex<HashMap<(usize, usize, usize, usize), Arc<Attention>>>,
}

impl NativeBackend {
    /// Build an interpreter over the artifacts' manifest (no compilation,
    /// no files beyond the manifest needed).
    pub fn new(artifacts: &Artifacts) -> Result<Self> {
        // surface a bad CBQ_THREADS / CBQ_SIMD here as a clean error
        // instead of a panic deep inside the first kernel call
        super::pool::validate_threads().map_err(|e| anyhow!(e))?;
        kernels::validate_simd().map_err(|e| anyhow!(e))?;
        Ok(Self {
            manifest: artifacts.manifest.clone(),
            stats: Mutex::new(RuntimeStats::default()),
            attn: Mutex::new(HashMap::new()),
        })
    }

    fn attention(&self, b: usize, s: usize, h: usize, hd: usize) -> Arc<Attention> {
        let key = (b, s, h, hd);
        let mut map = lock_or_recover(&self.attn);
        map.entry(key).or_insert_with(|| Arc::new(Attention::new(b, s, h, hd))).clone()
    }

    fn execute(
        &self,
        exec_name: &str,
        values: &BTreeMap<&str, &Value>,
    ) -> Result<BTreeMap<String, Tensor>> {
        let spec = self.spec(exec_name)?;
        // validate the shape/dtype of every *provided* declared input;
        // absent ones only error (with the same "missing input" message,
        // via `In::value`) if the executable actually consumes them — the
        // packed serving path legitimately omits s_w / v0 / LoRA factors
        // and the reconstruction target
        for ispec in &spec.inputs {
            if let Some(v) = values.get(ispec.name.as_str()) {
                check_shape(ispec, v)
                    .with_context(|| format!("input `{}` of {exec_name}", ispec.name))?;
            }
        }
        let (kind, cfg_name) = ExecKind::parse(exec_name).ok_or_else(|| {
            anyhow!("native backend cannot interpret executable name `{exec_name}`")
        })?;
        let cfg = self
            .manifest
            .configs
            .get(cfg_name)
            .ok_or_else(|| anyhow!("executable {exec_name}: unknown config `{cfg_name}`"))?;
        let inp = In { map: values, exec: exec_name };
        let t0 = std::time::Instant::now();
        let out = match kind {
            ExecKind::WinFwd { w } => self.win_fwd(&inp, cfg, w),
            ExecKind::WinGrad { w, dense } => self.win_grad(&inp, cfg, w, dense),
            ExecKind::Capture => self.capture(&inp, cfg),
            ExecKind::LmEval => self.lm_eval(&inp, cfg),
        }?;
        let mut s = lock_or_recover(&self.stats);
        s.executions += 1;
        s.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        Ok(out)
    }

    // -- executables ----------------------------------------------------

    fn win_fwd(&self, inp: &In, cfg: &ModelCfg, w: usize) -> Result<BTreeMap<String, Tensor>> {
        let glob = Glob::parse(inp)?;
        let h_in = inp.f32("h_in")?;
        // serving only consumes h_out; the packed pinning path therefore
        // skips binding a target and gets zero loss scalars back
        let target = inp.opt_f32("target")?;
        let rows = cfg.batch * cfg.seq;
        let mut h = h_in.data.to_vec();
        for j in 0..w {
            let blk = BlockRef::parse(inp, j)?;
            let qb = QBlockRef::parse(inp, j, false)?;
            let (h_out, _) = self.block_fwd(&h, rows, cfg, &blk, &qb, &glob, false, None)?;
            h = h_out;
        }
        let (loss, mse, kld) = match target {
            Some(t) => kernels::recon_loss(&h, &t.data, cfg.d_model, glob.l2_w, glob.kld_w),
            None => (0.0, 0.0, 0.0),
        };
        let mut out = BTreeMap::new();
        out.insert("h_out".into(), Tensor::new(h_in.dims.clone(), h));
        out.insert("loss".into(), Tensor::scalar(loss));
        out.insert("mse".into(), Tensor::scalar(mse));
        out.insert("kld".into(), Tensor::scalar(kld));
        Ok(out)
    }

    fn win_grad(
        &self,
        inp: &In,
        cfg: &ModelCfg,
        w: usize,
        dense: bool,
    ) -> Result<BTreeMap<String, Tensor>> {
        let glob = Glob::parse(inp)?;
        let h_in = inp.f32("h_in")?;
        let target = inp.f32("target")?;
        let rows = cfg.batch * cfg.seq;
        let d = cfg.d_model;

        // forward with caches
        let mut blocks = Vec::with_capacity(w);
        let mut qblocks = Vec::with_capacity(w);
        let mut caches = Vec::with_capacity(w);
        let mut h = h_in.data.to_vec();
        for j in 0..w {
            let blk = BlockRef::parse(inp, j)?;
            let qb = QBlockRef::parse(inp, j, dense)?;
            let (h_out, cache) = self.block_fwd(&h, rows, cfg, &blk, &qb, &glob, true, None)?;
            h = h_out;
            blocks.push(blk);
            qblocks.push(qb);
            caches.push(cache.expect("grad forward must cache"));
        }
        let (rec, mse, kld) = kernels::recon_loss(&h, &target.data, d, glob.l2_w, glob.kld_w);

        // backward
        let mut dh = kernels::recon_loss_bwd(&h, &target.data, d, glob.l2_w, glob.kld_w);
        let mut com_total = 0.0f32;
        let mut out = BTreeMap::new();
        for j in (0..w).rev() {
            let (dh_in, grads) = self.block_bwd(
                rows,
                cfg,
                &blocks[j],
                &qblocks[j],
                &caches[j],
                &glob,
                &dh,
                &mut com_total,
            );
            dh = dh_in;
            for (l, gr) in grads {
                let p = format!("grads.{j}.{l}");
                out.insert(format!("{p}.s_w"), gr.ds_w);
                out.insert(format!("{p}.alpha"), Tensor::scalar(gr.dalpha));
                if let Some(a1) = gr.da1 {
                    out.insert(format!("{p}.a1"), a1);
                }
                if let Some(a2) = gr.da2 {
                    out.insert(format!("{p}.a2"), a2);
                }
                if let Some(v) = gr.dv {
                    out.insert(format!("{p}.v"), v);
                }
            }
        }
        out.insert("loss".into(), Tensor::scalar(rec + glob.gamma_c * com_total));
        out.insert("mse".into(), Tensor::scalar(mse));
        out.insert("kld".into(), Tensor::scalar(kld));
        out.insert("com".into(), Tensor::scalar(com_total));
        Ok(out)
    }

    fn capture(&self, inp: &In, cfg: &ModelCfg) -> Result<BTreeMap<String, Tensor>> {
        let glob = Glob::parse(inp)?;
        let h_in = inp.f32("h_in")?;
        let rows = cfg.batch * cfg.seq;
        let blk = BlockRef::parse(inp, 0)?;
        let qb = QBlockRef::parse(inp, 0, false)?;
        let mut cap: BTreeMap<&'static str, Vec<f32>> = BTreeMap::new();
        let (h, _) =
            self.block_fwd(&h_in.data, rows, cfg, &blk, &qb, &glob, false, Some(&mut cap))?;
        let mut out = BTreeMap::new();
        out.insert("h_out".into(), Tensor::new(h_in.dims.clone(), h));
        for l in LINEARS {
            let (fan_in, _) = cfg.linear_shape(l);
            let src = capture_source(l);
            let data = cap
                .get(src)
                .ok_or_else(|| anyhow!("capture source `{src}` missing for {l}"))?
                .clone();
            out.insert(format!("captures.{l}"), Tensor::new(vec![rows, fan_in], data));
        }
        Ok(out)
    }

    fn lm_eval(&self, inp: &In, cfg: &ModelCfg) -> Result<BTreeMap<String, Tensor>> {
        let h = inp.f32("h")?;
        let final_norm = inp.f32("final_norm")?;
        let head = inp.f32("head")?;
        let targets = inp.i32("targets")?;
        let mask = inp.f32("mask")?;
        let (b, s, d) = (cfg.batch, cfg.seq, cfg.d_model);
        let v = cfg.vocab;
        let rows = b * s;
        let hn = kernels::rmsnorm(&h.data, d, &final_norm.data);
        let logits = kernels::matmul(&hn, rows, d, &head.data, v);
        let logp = kernels::log_softmax_rows(&logits, v);
        let mut nll = vec![0.0f32; b];
        let mut count = vec![0.0f32; b];
        for bi in 0..b {
            for si in 0..s {
                let row = bi * s + si;
                let m = mask.data[row];
                let t = targets.data[row];
                ensure!(
                    t >= 0 && (t as usize) < v,
                    "lm_eval target {t} outside vocab {v} (row {row})"
                );
                nll[bi] += -logp[row * v + t as usize] * m;
                count[bi] += m;
            }
        }
        let mut out = BTreeMap::new();
        out.insert("nll".into(), Tensor::new(vec![b], nll));
        out.insert("count".into(), Tensor::new(vec![b], count));
        Ok(out)
    }

    // -- quantized transformer block ------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn block_fwd(
        &self,
        h_in: &[f32],
        rows: usize,
        cfg: &ModelCfg,
        blk: &BlockRef,
        qb: &QBlockRef,
        glob: &Glob,
        grad: bool,
        mut capture: Option<&mut BTreeMap<&'static str, Vec<f32>>>,
    ) -> Result<(Vec<f32>, Option<BlockCache>)> {
        let d = cfg.d_model;
        ensure!(h_in.len() == rows * d, "block input len {} != rows*d", h_in.len());
        let ul = glob.use_lora;
        let a = kernels::rmsnorm(h_in, d, &blk.attn_norm.data);
        if let Some(c) = capture.as_deref_mut() {
            c.insert("attn_in", a.clone());
        }
        let (q_y, c_wq) = qlinear_fwd(&a, rows, blk.lin("wq"), qb.get("wq"), ul, grad)?;
        let (k_y, c_wk) = qlinear_fwd(&a, rows, blk.lin("wk"), qb.get("wk"), ul, grad)?;
        let (v_y, c_wv) = qlinear_fwd(&a, rows, blk.lin("wv"), qb.get("wv"), ul, grad)?;
        let attn = self.attention(cfg.batch, cfg.seq, cfg.n_heads, cfg.head_dim);
        let (mix, heads) = attn.forward(&q_y, &k_y, &v_y, grad);
        if let Some(c) = capture.as_deref_mut() {
            c.insert("attn_mix", mix.clone());
        }
        let (wo_y, c_wo) = qlinear_fwd(&mix, rows, blk.lin("wo"), qb.get("wo"), ul, grad)?;
        let h_mid: Vec<f32> = h_in.iter().zip(&wo_y).map(|(&x, &y)| x + y).collect();
        let m = kernels::rmsnorm(&h_mid, d, &blk.mlp_norm.data);
        if let Some(c) = capture.as_deref_mut() {
            c.insert("mlp_in", m.clone());
        }
        let (gate, c_wgate) = qlinear_fwd(&m, rows, blk.lin("wgate"), qb.get("wgate"), ul, grad)?;
        let (up, c_wup) = qlinear_fwd(&m, rows, blk.lin("wup"), qb.get("wup"), ul, grad)?;
        let act: Vec<f32> = gate.iter().zip(&up).map(|(&g, &u)| kernels::silu(g) * u).collect();
        if let Some(c) = capture.as_deref_mut() {
            c.insert("mlp_act", act.clone());
        }
        let (down_y, c_wdown) =
            qlinear_fwd(&act, rows, blk.lin("wdown"), qb.get("wdown"), ul, grad)?;
        let h_out: Vec<f32> = h_mid.iter().zip(&down_y).map(|(&x, &y)| x + y).collect();
        let cache = if grad {
            let mut ql = BTreeMap::new();
            for (name, c) in [
                ("wq", c_wq),
                ("wk", c_wk),
                ("wv", c_wv),
                ("wo", c_wo),
                ("wgate", c_wgate),
                ("wup", c_wup),
                ("wdown", c_wdown),
            ] {
                ql.insert(name, c.expect("grad forward caches every linear"));
            }
            Some(BlockCache { h_in: h_in.to_vec(), h_mid, gate, up, heads, ql })
        } else {
            None
        };
        Ok((h_out, cache))
    }

    /// One transformer block applied to a single decoded position of one
    /// sequence (`h_in` is one `[d]` row), attending over `cache`'s prefix
    /// via [`Attention::attend_one`]. Everything outside attention is
    /// per-position arithmetic identical to [`Self::block_fwd`] with
    /// `rows == 1`, so the output is bitwise-equal to the corresponding
    /// position of a full prefill.
    fn block_decode_row(
        &self,
        attn: &Attention,
        h_in: &[f32],
        blk: &BlockRef,
        qb: &QBlockRef,
        glob: &Glob,
        cache: &mut KvCache,
    ) -> Result<Vec<f32>> {
        let d = h_in.len();
        let ul = glob.use_lora;
        let a = kernels::rmsnorm(h_in, d, &blk.attn_norm.data);
        let (q_y, _) = qlinear_fwd(&a, 1, blk.lin("wq"), qb.get("wq"), ul, false)?;
        let (k_y, _) = qlinear_fwd(&a, 1, blk.lin("wk"), qb.get("wk"), ul, false)?;
        let (v_y, _) = qlinear_fwd(&a, 1, blk.lin("wv"), qb.get("wv"), ul, false)?;
        let mix = attn.attend_one(&q_y, &k_y, &v_y, cache);
        let (wo_y, _) = qlinear_fwd(&mix, 1, blk.lin("wo"), qb.get("wo"), ul, false)?;
        let h_mid: Vec<f32> = h_in.iter().zip(&wo_y).map(|(&x, &y)| x + y).collect();
        let m = kernels::rmsnorm(&h_mid, d, &blk.mlp_norm.data);
        let (gate, _) = qlinear_fwd(&m, 1, blk.lin("wgate"), qb.get("wgate"), ul, false)?;
        let (up, _) = qlinear_fwd(&m, 1, blk.lin("wup"), qb.get("wup"), ul, false)?;
        let act: Vec<f32> = gate.iter().zip(&up).map(|(&g, &u)| kernels::silu(g) * u).collect();
        let (down_y, _) = qlinear_fwd(&act, 1, blk.lin("wdown"), qb.get("wdown"), ul, false)?;
        Ok(h_mid.iter().zip(&down_y).map(|(&x, &y)| x + y).collect())
    }

    /// Backward through one block. Returns `(dh_in, per-linear grads)`.
    #[allow(clippy::too_many_arguments)]
    fn block_bwd(
        &self,
        rows: usize,
        cfg: &ModelCfg,
        blk: &BlockRef,
        qb: &QBlockRef,
        cache: &BlockCache,
        glob: &Glob,
        dh_out: &[f32],
        com_total: &mut f32,
    ) -> (Vec<f32>, Vec<(&'static str, LinGrads)>) {
        let d = cfg.d_model;
        let ul = glob.use_lora;
        let (beta, gc) = (glob.beta, glob.gamma_c);
        let mut grads: Vec<(&'static str, LinGrads)> = Vec::with_capacity(7);
        let mut bwd = |name: &'static str, g: &[f32]| -> Vec<f32> {
            let (dx, lg) = qlinear_bwd(
                g,
                rows,
                blk.lin(name),
                qb.get(name),
                &cache.ql[name],
                ul,
                beta,
                gc,
                com_total,
            );
            grads.push((name, lg));
            dx
        };

        // h_out = h_mid + wdown(act)
        let dact = bwd("wdown", dh_out);
        // act = silu(gate) * up
        let mut dgate = vec![0.0f32; dact.len()];
        let mut dup = vec![0.0f32; dact.len()];
        for i in 0..dact.len() {
            dgate[i] = dact[i] * cache.up[i] * kernels::silu_d(cache.gate[i]);
            dup[i] = dact[i] * kernels::silu(cache.gate[i]);
        }
        let dm1 = bwd("wgate", &dgate);
        let dm2 = bwd("wup", &dup);
        let dm: Vec<f32> = dm1.iter().zip(&dm2).map(|(&a, &b)| a + b).collect();
        // m = rmsnorm(h_mid, mlp_norm); h_mid also feeds the residual
        let dmid_norm = kernels::rmsnorm_bwd(&cache.h_mid, d, &blk.mlp_norm.data, &dm, None);
        let dh_mid: Vec<f32> = dh_out.iter().zip(&dmid_norm).map(|(&a, &b)| a + b).collect();
        // h_mid = h_in + wo(mix)
        let dmix = bwd("wo", &dh_mid);
        let attn = self.attention(cfg.batch, cfg.seq, cfg.n_heads, cfg.head_dim);
        let (dq3, dk3, dv3) = attn.backward(&cache.heads, &dmix);
        let da_q = bwd("wq", &dq3);
        let da_k = bwd("wk", &dk3);
        let da_v = bwd("wv", &dv3);
        let da: Vec<f32> = da_q
            .iter()
            .zip(&da_k)
            .zip(&da_v)
            .map(|((&a, &b), &c)| a + b + c)
            .collect();
        // a = rmsnorm(h_in, attn_norm); h_in also feeds the residual
        let din_norm = kernels::rmsnorm_bwd(&cache.h_in, d, &blk.attn_norm.data, &da, None);
        let dh_in: Vec<f32> = dh_mid.iter().zip(&din_norm).map(|(&a, &b)| a + b).collect();
        (dh_in, grads)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn warmup(&self, name: &str) -> Result<()> {
        self.spec(name).map(|_| ())
    }

    fn pin(&self, exec_name: &str, values: &BTreeMap<String, Value>) -> Result<Pinned> {
        let spec = self.spec(exec_name)?;
        // retain only inputs the executable actually declares, validated now
        let mut kept = BTreeMap::new();
        for ispec in &spec.inputs {
            if let Some(v) = values.get(&ispec.name) {
                check_shape(ispec, v)
                    .with_context(|| format!("pinning `{}` of {exec_name}", ispec.name))?;
                kept.insert(ispec.name.clone(), v.clone());
            }
        }
        Ok(Pinned { exec_name: exec_name.to_string(), inner: PinnedInner::Native(kept) })
    }

    fn run(
        &self,
        exec_name: &str,
        values: &BTreeMap<String, Value>,
    ) -> Result<BTreeMap<String, Tensor>> {
        let merged: BTreeMap<&str, &Value> =
            values.iter().map(|(k, v)| (k.as_str(), v)).collect();
        self.execute(exec_name, &merged)
    }

    fn run_pinned(
        &self,
        pinned: &Pinned,
        values: &BTreeMap<String, Value>,
    ) -> Result<BTreeMap<String, Tensor>> {
        let stat = match &pinned.inner {
            PinnedInner::Native(m) => m,
            PinnedInner::Pjrt(_) => anyhow::bail!(
                "pinned handle for executable {} belongs to the pjrt backend",
                pinned.exec_name
            ),
        };
        let mut merged: BTreeMap<&str, &Value> =
            stat.iter().map(|(k, v)| (k.as_str(), v)).collect();
        for (k, v) in values {
            merged.insert(k.as_str(), v);
        }
        self.execute(&pinned.exec_name, &merged)
    }

    fn decode_step(
        &self,
        pinned: &Pinned,
        h: &Tensor,
        start: usize,
        kv: &mut [SeqKv],
    ) -> Result<Tensor> {
        let stat = match &pinned.inner {
            PinnedInner::Native(m) => m,
            PinnedInner::Pjrt(_) => bail!(
                "pinned handle for executable {} belongs to the pjrt backend",
                pinned.exec_name
            ),
        };
        let (kind, cfg_name) = ExecKind::parse(&pinned.exec_name).ok_or_else(|| {
            anyhow!("native backend cannot interpret executable name `{}`", pinned.exec_name)
        })?;
        let ExecKind::WinFwd { w } = kind else {
            bail!("decode_step needs a pinned win_fwd_* window, got `{}`", pinned.exec_name)
        };
        let cfg = self
            .manifest
            .configs
            .get(cfg_name)
            .ok_or_else(|| anyhow!("executable {}: unknown config `{cfg_name}`", pinned.exec_name))?;
        let d = cfg.d_model;
        ensure!(
            h.dims.len() == 3 && h.dims[1] == 1 && h.dims[2] == d,
            "decode_step hidden must be [rows, 1, {d}], got {:?}",
            h.dims
        );
        let rows = h.dims[0];
        ensure!(rows > 0, "decode_step needs at least one row");
        ensure!(
            rows == kv.len(),
            "decode_step got {rows} hidden rows but {} KV states",
            kv.len()
        );
        ensure!(
            start + w <= cfg.n_layers,
            "window [{start}, {}) exceeds the model's {} blocks",
            start + w,
            cfg.n_layers
        );
        let map: BTreeMap<&str, &Value> = stat.iter().map(|(k, v)| (k.as_str(), v)).collect();
        let inp = In { map: &map, exec: &pinned.exec_name };
        let glob = Glob::parse(&inp)?;
        let attn = self.attention(cfg.batch, cfg.seq, cfg.n_heads, cfg.head_dim);
        let t0 = std::time::Instant::now();
        let mut hbuf = h.data.to_vec();
        for j in 0..w {
            let blk = BlockRef::parse(&inp, j)?;
            let qb = QBlockRef::parse(&inp, j, false)?;
            for (r, seq_kv) in kv.iter_mut().enumerate() {
                ensure!(
                    seq_kv.blocks.len() == cfg.n_layers,
                    "sequence {r}: KV state spans {} blocks, model has {}",
                    seq_kv.blocks.len(),
                    cfg.n_layers
                );
                let out = self.block_decode_row(
                    &attn,
                    &hbuf[r * d..(r + 1) * d],
                    &blk,
                    &qb,
                    &glob,
                    &mut seq_kv.blocks[start + j],
                )?;
                hbuf[r * d..(r + 1) * d].copy_from_slice(&out);
            }
        }
        let mut s = lock_or_recover(&self.stats);
        s.executions += 1;
        s.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        Ok(Tensor::new(vec![rows, 1, d], hbuf))
    }

    fn stats(&self) -> RuntimeStats {
        lock_or_recover(&self.stats).clone()
    }
}
