//! Persistent worker pool for the native backend's kernels.
//!
//! PR 2's kernels spawned a fresh `std::thread::scope` per call — correct,
//! but every matmul paid thread create/join. This module keeps one
//! process-wide pool of workers (lazily started on first use, sized by
//! [`num_threads`] / `CBQ_THREADS`) fed through a channel-style shared
//! queue; kernels submit borrowed-closure task batches via [`run_scoped`],
//! which blocks until the whole batch completed.
//!
//! Properties the kernels rely on:
//!
//! * **Scoped borrows.** Tasks may borrow the caller's stack (`&mut` output
//!   chunks, `&` inputs). [`run_scoped`] erases the lifetime to hand the
//!   closures to the workers, and is sound because it never returns before
//!   every task has run to completion (completion latch) — the borrowed
//!   frame outlives all uses.
//! * **No deadlock under nesting.** The concurrent serve dispatcher runs
//!   window executions on worker threads which themselves call kernels that
//!   call [`run_scoped`]. A waiting submitter therefore *helps*: while its
//!   latch is open it drains tasks from the shared queue instead of
//!   blocking, so queued work always makes progress even when every
//!   dedicated worker is itself inside a nested wait.
//! * **Determinism.** The pool only changes *where* tasks run, never how
//!   work is chunked: the kernels keep their fixed chunking scheme and each
//!   output element is written by exactly one task with a sequential
//!   reduction, so results are bit-identical for any worker count.
//! * **Panic propagation.** A panicking task is caught on the worker, the
//!   batch is still driven to completion, and the panic resurfaces in the
//!   submitting thread.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

use super::lock_or_recover;

/// Parse a `CBQ_THREADS` value: `None` when unset/blank (use auto-detect),
/// `Some(n)` for a valid explicit count, `Err` for `0` or garbage. Pure so
/// the rejection rules are unit-testable without touching the process env.
fn parse_threads(raw: Option<&str>) -> Result<Option<usize>, String> {
    let Some(raw) = raw else { return Ok(None) };
    let v = raw.trim();
    if v.is_empty() {
        return Ok(None);
    }
    match v.parse::<usize>() {
        Ok(0) => Err(format!(
            "CBQ_THREADS={raw}: thread count must be at least 1 (unset the \
             variable to auto-detect from available parallelism)"
        )),
        Ok(n) => Ok(Some(n.min(64))),
        Err(_) => Err(format!(
            "CBQ_THREADS={raw}: expected a positive integer thread count \
             (unset the variable to auto-detect from available parallelism)"
        )),
    }
}

/// Validate the `CBQ_THREADS` environment variable without starting the
/// pool. Backend constructors call this so a bad override fails loudly at
/// startup with a clear message instead of being silently ignored.
pub fn validate_threads() -> Result<(), String> {
    let raw = std::env::var("CBQ_THREADS").ok();
    parse_threads(raw.as_deref()).map(|_| ())
}

/// Worker thread count: `CBQ_THREADS` override, else available parallelism
/// capped at 16 (diminishing returns for the small reproduction models).
/// Resolved once per process — this sits on the hot path of every kernel,
/// and both the env var and the core count are fixed for the run.
///
/// A set-but-invalid `CBQ_THREADS` (zero or unparseable) panics with the
/// validation message rather than silently falling back to auto-detect;
/// call [`validate_threads`] at startup to surface the same error as a
/// `Result` instead.
pub fn num_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        let raw = std::env::var("CBQ_THREADS").ok();
        match parse_threads(raw.as_deref()) {
            Ok(Some(n)) => n,
            Ok(None) => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .clamp(1, 16),
            Err(e) => panic!("{e}"),
        }
    })
}

type Task = Box<dyn FnOnce() + Send>;

struct Queue {
    tasks: Mutex<VecDeque<Task>>,
    ready: Condvar,
}

impl Queue {
    fn lock(&self) -> MutexGuard<'_, VecDeque<Task>> {
        lock_or_recover(&self.tasks)
    }

    fn try_pop(&self) -> Option<Task> {
        self.lock().pop_front()
    }
}

/// Completion latch for one [`run_scoped`] batch.
struct Latch {
    /// (tasks still running, any task panicked)
    state: Mutex<(usize, bool)>,
    done: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Self { state: Mutex::new((n, false)), done: Condvar::new() }
    }

    fn complete(&self, panicked: bool) {
        let mut s = lock_or_recover(&self.state);
        s.0 -= 1;
        s.1 |= panicked;
        if s.0 == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        lock_or_recover(&self.state).0 == 0
    }

    /// Block until every task completed; returns the panicked flag.
    fn wait(&self) -> bool {
        let mut s = lock_or_recover(&self.state);
        while s.0 > 0 {
            s = self.done.wait(s).unwrap_or_else(|e| e.into_inner());
        }
        s.1
    }
}

struct Pool {
    queue: Arc<Queue>,
}

fn worker_loop(queue: Arc<Queue>) {
    loop {
        let task = {
            let mut guard = queue.lock();
            loop {
                if let Some(t) = guard.pop_front() {
                    break t;
                }
                guard = queue.ready.wait(guard).unwrap_or_else(|e| e.into_inner());
            }
        };
        task(); // already wrapped in catch_unwind by run_scoped
    }
}

fn global() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let queue = Arc::new(Queue { tasks: Mutex::new(VecDeque::new()), ready: Condvar::new() });
        for i in 0..num_threads() {
            let q = queue.clone();
            std::thread::Builder::new()
                .name(format!("cbq-pool-{i}"))
                .spawn(move || worker_loop(q))
                .expect("spawning cbq pool worker");
        }
        Pool { queue }
    })
}

/// Execute a batch of tasks on the persistent pool, returning once every
/// task has completed. Tasks may borrow the caller's stack frame. The
/// submitting thread participates (helping-wait), so nested `run_scoped`
/// calls from worker threads cannot deadlock the pool.
pub fn run_scoped<'scope>(tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
    match tasks.len() {
        0 => return,
        1 => {
            // nothing to parallelize: run inline, skip the queue round-trip
            (tasks.into_iter().next().expect("len checked"))();
            return;
        }
        _ => {}
    }
    let pool = global();
    let latch = Arc::new(Latch::new(tasks.len()));
    {
        let mut guard = pool.queue.lock();
        for t in tasks {
            // SAFETY: the closure may borrow the caller's stack ('scope).
            // run_scoped blocks on `latch` until every task has finished
            // executing (completion is signalled *after* the task returns,
            // panics included), so every borrow ends before this frame
            // does — the 'static erasure is never observable.
            let t = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'scope>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(t)
            };
            let l = latch.clone();
            guard.push_back(Box::new(move || {
                let r = catch_unwind(AssertUnwindSafe(t));
                l.complete(r.is_err());
            }));
        }
        pool.queue.ready.notify_all();
    }
    // helping-wait: drain the shared queue while our batch is in flight.
    // Only sleep when the queue is momentarily empty — then our remaining
    // tasks are running on other threads and their completion wakes us.
    let panicked = loop {
        if latch.is_done() {
            break latch.wait();
        }
        match pool.queue.try_pop() {
            Some(task) => task(),
            None => break latch.wait(),
        }
    };
    if panicked {
        panic!("cbq worker-pool task panicked (see worker output above)");
    }
}

/// Enqueue one fire-and-forget task on the persistent pool and return
/// immediately. Unlike [`run_scoped`] there is no completion latch: the
/// caller never waits, so the closure must own everything it touches
/// (`'static`). A panic inside the task is caught and dropped — detached
/// work is advisory by contract (its only current use is mmap window
/// prefetch, where failure just means the pages fault in later).
pub fn spawn_detached(task: impl FnOnce() + Send + 'static) {
    let pool = global();
    let mut guard = pool.queue.lock();
    guard.push_back(Box::new(move || {
        let _ = catch_unwind(AssertUnwindSafe(task));
    }));
    pool.queue.ready.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn thread_env_parse_accepts_valid_and_rejects_invalid() {
        assert_eq!(parse_threads(None), Ok(None));
        assert_eq!(parse_threads(Some("")), Ok(None));
        assert_eq!(parse_threads(Some("   ")), Ok(None));
        assert_eq!(parse_threads(Some("1")), Ok(Some(1)));
        assert_eq!(parse_threads(Some(" 8 ")), Ok(Some(8)));
        assert_eq!(parse_threads(Some("4096")), Ok(Some(64)), "capped at 64");
        for bad in ["0", "-2", "two", "1.5", "0x4"] {
            let err = parse_threads(Some(bad)).expect_err(bad);
            assert!(err.contains("CBQ_THREADS"), "error names the variable: {err}");
            assert!(err.contains("auto-detect"), "error explains the fix: {err}");
        }
    }

    #[test]
    fn runs_every_task_with_borrows() {
        let mut out = vec![0usize; 100];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .chunks_mut(7)
            .enumerate()
            .map(|(ci, chunk)| {
                Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = ci * 7 + j + 1;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_scoped(tasks);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i + 1);
        }
    }

    #[test]
    fn nested_run_scoped_makes_progress() {
        // every outer task fans out again: exercises the helping-wait path
        // that prevents worker-starvation deadlocks
        let total = Arc::new(AtomicUsize::new(0));
        let outer: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2 * num_threads().max(2))
            .map(|_| {
                let total = total.clone();
                Box::new(move || {
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                        .map(|_| {
                            let total = total.clone();
                            Box::new(move || {
                                total.fetch_add(1, Ordering::Relaxed);
                            })
                                as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    run_scoped(inner);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        let n_outer = outer.len();
        run_scoped(outer);
        assert_eq!(total.load(Ordering::Relaxed), n_outer * 4);
    }

    #[test]
    fn concurrent_submitters_do_not_interfere() {
        // several OS threads submitting batches at once: each batch's own
        // buffer must come back fully and correctly written
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut out = vec![0usize; 64];
                    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
                        .chunks_mut(5)
                        .map(|chunk| {
                            Box::new(move || {
                                for v in chunk.iter_mut() {
                                    *v = t + 1;
                                }
                            }) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    run_scoped(tasks);
                    assert!(out.iter().all(|&v| v == t + 1));
                })
            })
            .collect();
        for h in handles {
            h.join().expect("submitter thread panicked");
        }
    }

    #[test]
    fn task_panic_propagates_to_submitter() {
        let caught = std::panic::catch_unwind(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|i| {
                    Box::new(move || {
                        if i == 2 {
                            panic!("boom");
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            run_scoped(tasks);
        });
        assert!(caught.is_err(), "pool swallowed a task panic");
        // the pool must remain usable afterwards
        let mut out = vec![0u8; 16];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .chunks_mut(3)
            .map(|c| {
                Box::new(move || c.fill(1)) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_scoped(tasks);
        assert!(out.iter().all(|&v| v == 1));
    }
}
