//! Execution substrate: artifacts + manifest on disk, executable backends
//! behind the [`Backend`] trait.
//!
//! * [`Artifacts`] — the artifacts directory (manifest + optional HLO text
//!   files + weights + corpus parity vectors). Produced either by
//!   `python/compile/aot.py` (`make artifacts`, trained reference models)
//!   or by [`synth`] / `cbq synth` (tiny synthetic models, host-only).
//! * [`backend`] — the [`Backend`] trait with the PJRT implementation
//!   (compiles the AOT HLO) and the native CPU implementation (interprets
//!   the manifest semantics directly, including `win_grad_*` gradients).
//! * [`synth`] — synthetic artifact generator: manifest + pretrained-on-host
//!   random-init weights + corpus reference, so every pipeline stage runs
//!   end-to-end offline.
//!
//! Backend selection: `--backend native|pjrt|auto` / `CBQ_BACKEND`, see
//! [`backend::create_selected`].

pub mod backend;
pub mod manifest;
pub mod synth;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

pub use backend::{
    create as create_backend, create_selected, Backend, BackendKind, KvCache, NativeBackend,
    Pinned, PjrtBackend, RuntimeStats, SeqKv,
};
pub use manifest::{ExecSpec, Manifest, ModelCfg, TensorSpec};

use crate::tensor::{io, Tensor, TensorI32};
use backend::kernels::QPanels;

/// A packed-domain weight operand: pre-panelized quantized codes + scales
/// ([`QPanels`]) shared via `Arc`, standing in for the f32 weight tensor an
/// executable input declares. Its logical dims are the dequantized shape
/// `[k, n]`, so shape checks treat it like the f32 tensor it replaces; the
/// native backend's quantized matmul consumes the codes directly.
#[derive(Clone, Debug)]
pub struct PackedValue {
    dims: [usize; 2],
    panels: Arc<QPanels>,
}

impl PackedValue {
    /// Wrap pre-built panels (cheap to clone — engines sharing a window
    /// share one code buffer).
    pub fn new(panels: Arc<QPanels>) -> Self {
        Self { dims: panels.dims(), panels }
    }

    /// The shared panels.
    pub fn panels(&self) -> &Arc<QPanels> {
        &self.panels
    }
}

/// A typed runtime value bound to an executable input.
#[derive(Clone, Debug)]
pub enum Value {
    /// A float tensor.
    F32(Tensor),
    /// An int32 tensor (token ids, targets).
    I32(TensorI32),
    /// A packed-domain quantized weight (codes + scales, no f32 copy).
    Packed(PackedValue),
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Self {
        Value::F32(t)
    }
}

impl From<TensorI32> for Value {
    fn from(t: TensorI32) -> Self {
        Value::I32(t)
    }
}

impl Value {
    /// The tensor's shape, dtype-independent (a packed weight reports its
    /// dequantized `[k, n]` shape).
    pub fn dims(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.dims,
            Value::I32(t) => &t.dims,
            Value::Packed(p) => &p.dims,
        }
    }

    /// Heap bytes the underlying storage keeps resident (0 for
    /// memory-mapped views — see [`crate::tensor::Storage::heap_bytes`];
    /// codes + scales for a packed weight).
    pub fn heap_bytes(&self) -> usize {
        match self {
            Value::F32(t) => t.data.heap_bytes(),
            Value::I32(t) => t.data.heap_bytes(),
            Value::Packed(p) => p.panels.heap_bytes(),
        }
    }

    /// Address of the first element — the identity key residency
    /// accounting dedups shared buffers by. Prefer
    /// [`Value::heap_components`] for accounting: a packed value owns
    /// *two* buffers and this returns only the code buffer's address.
    pub fn data_ptr(&self) -> usize {
        match self {
            Value::F32(t) => t.data.as_ptr() as usize,
            Value::I32(t) => t.data.as_ptr() as usize,
            Value::Packed(p) => p.panels.codes_ptr(),
        }
    }

    /// Every distinct owned heap buffer behind this value as
    /// `(address, bytes)` pairs — empty for mapped storage (the bytes
    /// belong to the file mapping, not the process heap). Residency
    /// accounting dedups on the address so buffers shared across values
    /// (Arc clones) are counted once.
    pub fn heap_components(&self) -> Vec<(usize, usize)> {
        match self {
            Value::F32(t) => {
                let b = t.data.heap_bytes();
                if b > 0 {
                    vec![(t.data.as_ptr() as usize, b)]
                } else {
                    Vec::new()
                }
            }
            Value::I32(t) => {
                let b = t.data.heap_bytes();
                if b > 0 {
                    vec![(t.data.as_ptr() as usize, b)]
                } else {
                    Vec::new()
                }
            }
            Value::Packed(p) => vec![
                (p.panels.codes_ptr(), p.panels.code_bytes()),
                (p.panels.scales_ptr(), p.panels.scale_bytes()),
            ],
        }
    }

    /// Is the underlying storage a borrowed-from-file mapped view?
    pub fn is_mapped(&self) -> bool {
        match self {
            Value::F32(t) => t.data.is_mapped(),
            Value::I32(t) => t.data.is_mapped(),
            Value::Packed(_) => false,
        }
    }
}

/// The artifacts directory: manifest + executables' files + weights.
pub struct Artifacts {
    /// Directory the artifacts were loaded from.
    pub dir: PathBuf,
    /// The parsed manifest (configs, executables, windows).
    pub manifest: Manifest,
}

impl Artifacts {
    /// Load `dir/manifest.json` and wrap the directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        Ok(Self { dir, manifest })
    }

    /// Default location: `$CBQ_ARTIFACTS` or `artifacts/` relative to cwd
    /// (falling back to the crate root for `cargo test` / `cargo bench`).
    pub fn discover() -> Result<Self> {
        if let Ok(p) = std::env::var("CBQ_ARTIFACTS") {
            return Self::load(p);
        }
        for cand in ["artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")] {
            if Path::new(cand).join("manifest.json").exists() {
                return Self::load(cand);
            }
        }
        bail!(
            "no artifacts directory found — run `make artifacts` (trained models) \
             or `cbq synth` (synthetic offline models) first"
        )
    }

    /// The model config registered under `name`.
    pub fn cfg(&self, name: &str) -> Result<&ModelCfg> {
        self.manifest
            .configs
            .get(name)
            .ok_or_else(|| anyhow!("unknown model config {name}"))
    }

    /// The model to operate on when the CLI gives none: the sole config if
    /// there is exactly one (the `cbq synth` case), else `s`.
    pub fn default_model(&self) -> &str {
        if self.manifest.configs.len() == 1 {
            self.manifest.configs.keys().next().map(|s| s.as_str()).unwrap_or("s")
        } else {
            "s"
        }
    }

    /// `preferred` when the manifest carries it (e.g. the small trained `t`
    /// model of `make artifacts` builds), else [`Self::default_model`] —
    /// the model-pick policy shared by the examples and integration tests.
    pub fn model_or_default<'a>(&'a self, preferred: &'a str) -> &'a str {
        if self.manifest.configs.contains_key(preferred) {
            preferred
        } else {
            self.default_model()
        }
    }

    /// Pretrained (outlier-injected) weights for a config.
    pub fn weights(&self, cfg: &str) -> Result<BTreeMap<String, Tensor>> {
        io::read_tensors(self.dir.join(format!("weights_{cfg}.bin")))
    }

    /// Exported window sizes for a config; `[1]` when the manifest lists
    /// none (the single source of the fallback shared by eval and serve).
    pub fn windows(&self, cfg: &str) -> Vec<usize> {
        self.manifest.windows.get(cfg).cloned().unwrap_or_else(|| vec![1])
    }

    /// Cross-language corpus parity vectors (first 2048 tokens per style).
    pub fn corpus_ref(&self) -> Result<BTreeMap<String, Vec<u32>>> {
        let raw = std::fs::read_to_string(self.dir.join("corpus_ref.json"))?;
        let v = crate::json::parse(&raw)?;
        let mut out = BTreeMap::new();
        for (k, arr) in v.as_obj()? {
            out.insert(
                k.clone(),
                arr.as_arr()?
                    .iter()
                    .map(|t| Ok(t.as_usize()? as u32))
                    .collect::<Result<Vec<u32>>>()?,
            );
        }
        Ok(out)
    }
}

/// Convenience builder for name-bound inputs.
#[derive(Default, Clone, Debug)]
pub struct Bindings(pub BTreeMap<String, Value>);

impl Bindings {
    /// Empty binding set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bind an f32 tensor under `name`.
    pub fn set(&mut self, name: impl Into<String>, t: Tensor) -> &mut Self {
        self.0.insert(name.into(), Value::F32(t));
        self
    }

    /// Bind an i32 tensor under `name`.
    pub fn set_i32(&mut self, name: impl Into<String>, t: TensorI32) -> &mut Self {
        self.0.insert(name.into(), Value::I32(t));
        self
    }

    /// Bind a 0-d f32 tensor under `name`.
    pub fn scalar(&mut self, name: impl Into<String>, v: f32) -> &mut Self {
        self.0.insert(name.into(), Value::F32(Tensor::scalar(v)));
        self
    }

    /// Fold another binding set in (later keys win).
    pub fn merge(&mut self, other: Bindings) -> &mut Self {
        self.0.extend(other.0);
        self
    }

    /// The name → value map backends consume.
    pub fn inner(&self) -> &BTreeMap<String, Value> {
        &self.0
    }
}
