//! PJRT execution substrate: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client, and
//! exposes a name-bound `run` interface driven by the manifest's
//! flatten_spec contract.
//!
//! Python is never on this path — the HLO text was lowered at build time;
//! this module only parses, compiles and executes.
//!
//! Hot-path notes (see EXPERIMENTS.md §Perf): executables are compiled
//! lazily and cached for the process lifetime; static inputs (model weights)
//! can be pinned as device buffers via [`Runtime::pin`] so steady-state
//! window steps only upload the small learnable tensors.

pub mod manifest;

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{ExecSpec, Manifest, ModelCfg, TensorSpec};

use crate::tensor::{io, Tensor, TensorI32};

/// A typed runtime value bound to an executable input.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(TensorI32),
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Self {
        Value::F32(t)
    }
}

impl From<TensorI32> for Value {
    fn from(t: TensorI32) -> Self {
        Value::I32(t)
    }
}

impl Value {
    fn dims(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.dims,
            Value::I32(t) => &t.dims,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Value::F32(t) => {
                if t.dims.is_empty() {
                    xla::Literal::scalar(t.data[0])
                } else {
                    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(&t.data).reshape(&dims).map_err(xerr)?
                }
            }
            Value::I32(t) => {
                if t.dims.is_empty() {
                    xla::Literal::scalar(t.data[0])
                } else {
                    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(&t.data).reshape(&dims).map_err(xerr)?
                }
            }
        };
        Ok(lit)
    }
}

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

/// The artifacts directory: manifest + HLO files + pretrained weights.
pub struct Artifacts {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl Artifacts {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        Ok(Self { dir, manifest })
    }

    /// Default location: `$CBQ_ARTIFACTS` or `artifacts/` relative to cwd
    /// (falling back to the crate root for `cargo test` / `cargo bench`).
    pub fn discover() -> Result<Self> {
        if let Ok(p) = std::env::var("CBQ_ARTIFACTS") {
            return Self::load(p);
        }
        for cand in ["artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")] {
            if Path::new(cand).join("manifest.json").exists() {
                return Self::load(cand);
            }
        }
        bail!("no artifacts directory found — run `make artifacts` first")
    }

    pub fn cfg(&self, name: &str) -> Result<&ModelCfg> {
        self.manifest
            .configs
            .get(name)
            .ok_or_else(|| anyhow!("unknown model config {name}"))
    }

    /// Pretrained (outlier-injected) weights for a config.
    pub fn weights(&self, cfg: &str) -> Result<BTreeMap<String, Tensor>> {
        io::read_tensors(self.dir.join(format!("weights_{cfg}.bin")))
    }

    /// Exported window sizes for a config; `[1]` when the manifest lists
    /// none (the single source of the fallback shared by eval and serve).
    pub fn windows(&self, cfg: &str) -> Vec<usize> {
        self.manifest.windows.get(cfg).cloned().unwrap_or_else(|| vec![1])
    }

    /// Cross-language corpus parity vectors (first 2048 tokens per style).
    pub fn corpus_ref(&self) -> Result<BTreeMap<String, Vec<u32>>> {
        let raw = std::fs::read_to_string(self.dir.join("corpus_ref.json"))?;
        let v = crate::json::parse(&raw)?;
        let mut out = BTreeMap::new();
        for (k, arr) in v.as_obj()? {
            out.insert(
                k.clone(),
                arr.as_arr()?
                    .iter()
                    .map(|t| Ok(t.as_usize()? as u32))
                    .collect::<Result<Vec<u32>>>()?,
            );
        }
        Ok(out)
    }
}

struct LoadedExec {
    exe: xla::PjRtLoadedExecutable,
    spec: ExecSpec,
}

/// Pinned device buffers for an executable's static inputs (weights): the
/// steady-state optimization loop re-uploads only learnable tensors.
///
/// The source literals are retained: TfrtCpuBuffer's CopyFromLiteral is
/// asynchronous and reads the literal after `buffer_from_host_literal`
/// returns — dropping the literal early is a use-after-free.
pub struct Pinned {
    exec_name: String,
    /// input index -> device buffer
    buffers: HashMap<usize, xla::PjRtBuffer>,
    _literals: Vec<xla::Literal>,
}

/// Runtime statistics (coordinator overhead accounting for §Perf).
#[derive(Default, Debug, Clone)]
pub struct RuntimeStats {
    pub executions: u64,
    pub compile_ms: f64,
    pub execute_ms: f64,
    pub upload_bytes: u64,
}

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    execs: RefCell<HashMap<String, Rc<LoadedExec>>>,
    manifest: Manifest,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    pub fn new(artifacts: &Artifacts) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Self {
            client,
            dir: artifacts.dir.clone(),
            execs: RefCell::new(HashMap::new()),
            manifest: artifacts.manifest.clone(),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn spec(&self, name: &str) -> Result<&ExecSpec> {
        self.manifest
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown executable {name}"))
    }

    fn load(&self, name: &str) -> Result<Rc<LoadedExec>> {
        if let Some(e) = self.execs.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.spec(name)?.clone();
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(xerr)
        .with_context(|| format!("loading HLO {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xerr)?;
        self.stats.borrow_mut().compile_ms += t0.elapsed().as_secs_f64() * 1e3;
        let e = Rc::new(LoadedExec { exe, spec });
        self.execs.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Eagerly compile an executable (startup warm-up).
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.load(name).map(|_| ())
    }

    /// Pin a set of inputs (by name) as device buffers. Returns a handle
    /// usable with [`Runtime::run_pinned`].
    pub fn pin(&self, exec_name: &str, values: &BTreeMap<String, Value>) -> Result<Pinned> {
        let exec = self.load(exec_name)?;
        let mut buffers = HashMap::new();
        let mut literals = Vec::new();
        for (idx, spec) in exec.spec.inputs.iter().enumerate() {
            if let Some(v) = values.get(&spec.name) {
                check_shape(spec, v)?;
                let lit = v.to_literal()?;
                let buf = self
                    .client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(xerr)?;
                buffers.insert(idx, buf);
                literals.push(lit); // keep alive: async host->device copy
            }
        }
        Ok(Pinned { exec_name: exec_name.to_string(), buffers, _literals: literals })
    }

    /// Execute with every input bound by name from `values`.
    pub fn run(
        &self,
        exec_name: &str,
        values: &BTreeMap<String, Value>,
    ) -> Result<BTreeMap<String, Tensor>> {
        self.run_inner(exec_name, values, None)
    }

    /// Execute with `pinned` supplying the static inputs and `values` the
    /// dynamic remainder.
    pub fn run_pinned(
        &self,
        pinned: &Pinned,
        values: &BTreeMap<String, Value>,
    ) -> Result<BTreeMap<String, Tensor>> {
        self.run_inner(&pinned.exec_name, values, Some(pinned))
    }

    fn run_inner(
        &self,
        exec_name: &str,
        values: &BTreeMap<String, Value>,
        pinned: Option<&Pinned>,
    ) -> Result<BTreeMap<String, Tensor>> {
        let exec = self.load(exec_name)?;
        // Fresh (dynamic) uploads, keyed by input index; pinned buffers are
        // borrowed directly — PJRT `Execute` with default options does not
        // donate inputs, so reuse across calls is sound. Source literals are
        // kept alive until execution completes (async host->device copies).
        let mut fresh: HashMap<usize, xla::PjRtBuffer> = HashMap::new();
        let mut fresh_lits: Vec<xla::Literal> = Vec::new();
        let mut upload = 0u64;
        for (idx, spec) in exec.spec.inputs.iter().enumerate() {
            if let Some(p) = pinned {
                if p.buffers.contains_key(&idx) {
                    continue;
                }
            }
            let v = values.get(&spec.name).ok_or_else(|| {
                anyhow!("missing input `{}` for executable {exec_name}", spec.name)
            })?;
            check_shape(spec, v)
                .with_context(|| format!("input `{}` of {exec_name}", spec.name))?;
            upload += (v.dims().iter().product::<usize>().max(1) * 4) as u64;
            let lit = v.to_literal()?;
            fresh.insert(
                idx,
                self.client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(xerr)?,
            );
            fresh_lits.push(lit);
        }
        let bufs: Vec<&xla::PjRtBuffer> = (0..exec.spec.inputs.len())
            .map(|idx| {
                fresh.get(&idx).unwrap_or_else(|| {
                    pinned
                        .expect("index neither fresh nor pinned")
                        .buffers
                        .get(&idx)
                        .expect("index neither fresh nor pinned")
                })
            })
            .collect();
        let t0 = std::time::Instant::now();
        let result = exec.exe.execute_b(&bufs).map_err(xerr)?;
        // blocks until execution (and hence input consumption) completes
        let tuple = result[0][0].to_literal_sync().map_err(xerr)?;
        drop(fresh_lits);
        let parts = tuple.to_tuple().map_err(xerr)?;
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
            s.upload_bytes += upload;
        }
        anyhow::ensure!(
            parts.len() == exec.spec.outputs.len(),
            "executable {exec_name}: {} outputs, manifest says {}",
            parts.len(),
            exec.spec.outputs.len()
        );
        let mut out = BTreeMap::new();
        for (spec, lit) in exec.spec.outputs.iter().zip(parts) {
            let data: Vec<f32> = match spec.dtype.as_str() {
                "float32" => lit.to_vec::<f32>().map_err(xerr)?,
                "int32" => lit
                    .to_vec::<i32>()
                    .map_err(xerr)?
                    .into_iter()
                    .map(|v| v as f32)
                    .collect(),
                d => bail!("unsupported output dtype {d}"),
            };
            out.insert(spec.name.clone(), Tensor::new(spec.shape.clone(), data));
        }
        Ok(out)
    }
}

fn check_shape(spec: &TensorSpec, v: &Value) -> Result<()> {
    let want: &[usize] = &spec.shape;
    let got = v.dims();
    anyhow::ensure!(got == want, "shape mismatch: got {:?}, manifest wants {:?}", got, want);
    let is_i32 = matches!(v, Value::I32(_));
    let want_i32 = spec.dtype == "int32";
    anyhow::ensure!(
        is_i32 == want_i32,
        "dtype mismatch: got {}, manifest wants {}",
        if is_i32 { "int32" } else { "float32" },
        spec.dtype
    );
    Ok(())
}

/// Convenience builder for name-bound inputs.
#[derive(Default, Clone, Debug)]
pub struct Bindings(pub BTreeMap<String, Value>);

impl Bindings {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&mut self, name: impl Into<String>, t: Tensor) -> &mut Self {
        self.0.insert(name.into(), Value::F32(t));
        self
    }

    pub fn set_i32(&mut self, name: impl Into<String>, t: TensorI32) -> &mut Self {
        self.0.insert(name.into(), Value::I32(t));
        self
    }

    pub fn scalar(&mut self, name: impl Into<String>, v: f32) -> &mut Self {
        self.0.insert(name.into(), Value::F32(Tensor::scalar(v)));
        self
    }

    pub fn merge(&mut self, other: Bindings) -> &mut Self {
        self.0.extend(other.0);
        self
    }

    pub fn inner(&self) -> &BTreeMap<String, Value> {
        &self.0
    }
}
