//! artifacts/manifest.json schema — written by python/compile/aot.py.
//!
//! The manifest is the single source of truth for executable input/output
//! orderings (the flatten_spec contract), model configurations, and the
//! window sizes exported per config. Parsed with the in-crate JSON parser
//! (crate::json) since the build environment only vendors the xla closure.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::json::{self, Value};

/// The parsed `manifest.json`: everything the runtime knows about the
/// artifacts without opening another file.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Manifest schema version.
    pub version: u32,
    /// Model configurations by name.
    pub configs: BTreeMap<String, ModelCfg>,
    /// Executable specs by name (`win_fwd_w2_s`, `lm_eval_s`, ...).
    pub executables: BTreeMap<String, ExecSpec>,
    /// Final pretraining loss per config (synthetic artifacts record it).
    pub pretrain_loss: BTreeMap<String, f64>,
    /// Linear names in canonical order (wq, wk, ...).
    pub linears: Vec<String>,
    /// Exported window sizes per config.
    pub windows: BTreeMap<String, Vec<usize>>,
}

/// One model configuration — also the snapshot fingerprint (every field is
/// compared by `snapshot::fingerprint_mismatches`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    /// Config name (manifest key).
    pub name: String,
    /// Hidden width.
    pub d_model: usize,
    /// Transformer block count.
    pub n_layers: usize,
    /// Attention head count.
    pub n_heads: usize,
    /// MLP hidden width.
    pub d_ffn: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Sequence length every executable is shaped for.
    pub seq: usize,
    /// Batch rows every executable is shaped for.
    pub batch: usize,
    /// Padded LoRA rank of the rounding factors.
    pub rank_pad: usize,
    /// Per-head width (`d_model / n_heads`).
    pub head_dim: usize,
    /// Number of outlier channels injected at synthesis (0 = none).
    pub outlier_channels: usize,
    /// Gain applied to injected outlier channels.
    pub outlier_gain: f64,
}

impl ModelCfg {
    /// JSON encoding (the snapshot header embeds the full config as the
    /// model fingerprint; `from_json` is its inverse and also parses the
    /// manifest's `configs` entries).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", Value::str(self.name.clone())),
            ("d_model", Value::num(self.d_model as f64)),
            ("n_layers", Value::num(self.n_layers as f64)),
            ("n_heads", Value::num(self.n_heads as f64)),
            ("d_ffn", Value::num(self.d_ffn as f64)),
            ("vocab", Value::num(self.vocab as f64)),
            ("seq", Value::num(self.seq as f64)),
            ("batch", Value::num(self.batch as f64)),
            ("rank_pad", Value::num(self.rank_pad as f64)),
            ("head_dim", Value::num(self.head_dim as f64)),
            ("outlier_channels", Value::num(self.outlier_channels as f64)),
            ("outlier_gain", Value::num(self.outlier_gain)),
        ])
    }

    /// Inverse of [`ModelCfg::to_json`].
    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            d_model: v.get("d_model")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            n_heads: v.get("n_heads")?.as_usize()?,
            d_ffn: v.get("d_ffn")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            seq: v.get("seq")?.as_usize()?,
            batch: v.get("batch")?.as_usize()?,
            rank_pad: v.get("rank_pad")?.as_usize()?,
            head_dim: v.get("head_dim")?.as_usize()?,
            outlier_channels: v
                .opt("outlier_channels")
                .map(|x| x.as_usize())
                .transpose()?
                .unwrap_or(0),
            outlier_gain: v.opt("outlier_gain").map(|x| x.as_f64()).transpose()?.unwrap_or(0.0),
        })
    }

    /// Input fan-in/fan-out of a linear by name (mirrors model.linear_shapes).
    pub fn linear_shape(&self, name: &str) -> (usize, usize) {
        let (d, f) = (self.d_model, self.d_ffn);
        match name {
            "wq" | "wk" | "wv" | "wo" => (d, d),
            "wgate" | "wup" => (d, f),
            "wdown" => (f, d),
            other => panic!("unknown linear {other}"),
        }
    }

    /// Total quantizable weight parameters.
    pub fn quant_params(&self) -> usize {
        let per_block: usize = crate::quant::LINEARS
            .iter()
            .map(|l| {
                let (i, o) = self.linear_shape(l);
                i * o
            })
            .sum();
        per_block * self.n_layers
    }
}

/// One executable's I/O contract (the flatten_spec ordering).
#[derive(Debug, Clone)]
pub struct ExecSpec {
    /// HLO file name inside the artifacts directory (PJRT path only).
    pub file: String,
    /// Declared inputs, in binding order.
    pub inputs: Vec<TensorSpec>,
    /// Declared outputs, in result order.
    pub outputs: Vec<TensorSpec>,
}

/// One named tensor in an executable's I/O contract.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    /// Binding name.
    pub name: String,
    /// Required shape.
    pub shape: Vec<usize>,
    /// "float32" or "int32".
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            shape: v
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            dtype: v.get("dtype")?.as_str()?.to_string(),
        })
    }
}

impl Manifest {
    /// Parse a `manifest.json` file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let raw = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading manifest {:?}", path.as_ref()))?;
        let v = json::parse(&raw).context("parsing manifest.json")?;
        let version = v.get("version")?.as_usize()? as u32;
        ensure!(version == 1, "unsupported manifest version {version}");

        let mut configs = BTreeMap::new();
        for (k, c) in v.get("configs")?.as_obj()? {
            configs.insert(k.clone(), ModelCfg::from_json(c)?);
        }
        let mut executables = BTreeMap::new();
        for (k, e) in v.get("executables")?.as_obj()? {
            executables.insert(
                k.clone(),
                ExecSpec {
                    file: e.get("file")?.as_str()?.to_string(),
                    inputs: e
                        .get("inputs")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                    outputs: e
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                },
            );
        }
        let mut pretrain_loss = BTreeMap::new();
        if let Some(pl) = v.opt("pretrain_loss") {
            for (k, x) in pl.as_obj()? {
                pretrain_loss.insert(k.clone(), x.as_f64()?);
            }
        }
        let linears = v
            .get("linears")?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        let mut windows = BTreeMap::new();
        for (k, arr) in v.get("windows")?.as_obj()? {
            windows.insert(
                k.clone(),
                arr.as_arr()?.iter().map(|d| d.as_usize()).collect::<Result<_>>()?,
            );
        }
        Ok(Self { version, configs, executables, pretrain_loss, linears, windows })
    }
}
