//! Table/figure rendering for the bench harnesses: fixed-width text tables
//! (the same rows the paper prints) + ASCII heatmaps for the Hessian
//! figures + CSV dumps for external plotting.

use std::fmt::Write as _;

/// Simple fixed-width table printer.
pub struct Table {
    /// Table caption.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows (cells pre-formatted).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a caption and column headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with box-drawing borders.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let line = |out: &mut String| {
            let _ = writeln!(
                out,
                "+{}+",
                widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("+")
            );
        };
        line(&mut out);
        let _ = writeln!(
            out,
            "|{}|",
            self.headers
                .iter()
                .zip(&widths)
                .map(|(h, w)| format!(" {h:<w$} "))
                .collect::<Vec<_>>()
                .join("|")
        );
        line(&mut out);
        for row in &self.rows {
            let _ = writeln!(
                out,
                "|{}|",
                row.iter()
                    .zip(&widths)
                    .map(|(c, w)| format!(" {c:<w$} "))
                    .collect::<Vec<_>>()
                    .join("|")
            );
        }
        line(&mut out);
        out
    }

    /// Print the rendered table to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// CSV rendering (quotes cells containing separators).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }
}

/// Human-readable byte counts for the snapshot/serve summaries.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 4] = ["B", "KiB", "MiB", "GiB"];
    let mut v = b as f64;
    let mut u = 0usize;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Fixed-precision float formatting helper.
pub fn fmt_f(v: f64, prec: usize) -> String {
    if !v.is_finite() {
        return "inf".into();
    }
    if v >= 1e4 {
        format!("{v:.1e}")
    } else {
        format!("{v:.prec$}")
    }
}

/// ASCII heatmap of a matrix using log-scaled magnitude shades.
pub fn heatmap(title: &str, m: &[Vec<f32>]) -> String {
    const SHADES: [char; 10] = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let mut mx = 0.0f32;
    for row in m {
        for &v in row {
            mx = mx.max(v.abs());
        }
    }
    let mut out = format!("\n-- {title} (max |H| = {mx:.3e}) --\n");
    for row in m {
        for &v in row {
            let t = if mx > 0.0 {
                ((v.abs() / mx).powf(0.35) * (SHADES.len() - 1) as f32).round() as usize
            } else {
                0
            };
            out.push(SHADES[t.min(SHADES.len() - 1)]);
            out.push(SHADES[t.min(SHADES.len() - 1)]);
        }
        out.push('\n');
    }
    out
}

/// CSV dump of a matrix.
pub fn matrix_csv(m: &[Vec<f32>]) -> String {
    m.iter()
        .map(|row| row.iter().map(|v| format!("{v:.6e}")).collect::<Vec<_>>().join(","))
        .collect::<Vec<_>>()
        .join("\n")
}

/// Histogram summary for Fig. 3-style outlier distribution dumps.
pub fn magnitude_histogram(title: &str, data: &[f32], buckets: usize) -> String {
    let mx = data.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-12);
    let mut counts = vec![0usize; buckets];
    for &v in data {
        let b = ((v.abs() / mx) * (buckets - 1) as f32).round() as usize;
        counts[b.min(buckets - 1)] += 1;
    }
    let peak = *counts.iter().max().unwrap_or(&1) as f32;
    let mut out = format!("\n-- {title} (max |x| = {mx:.4}) --\n");
    for (i, &c) in counts.iter().enumerate() {
        let lo = mx * i as f32 / buckets as f32;
        let bar = "#".repeat(((c as f32 / peak) * 50.0).ceil() as usize);
        let _ = writeln!(out, "{lo:>9.4} | {bar} {c}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["long-cell".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("long-cell"));
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("demo", &["x", "y"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv().lines().count(), 2);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.00 MiB");
    }

    #[test]
    fn heatmap_handles_zero_matrix() {
        let s = heatmap("z", &[vec![0.0, 0.0], vec![0.0, 0.0]]);
        assert!(s.contains("z"));
    }

    #[test]
    fn histogram_counts_all() {
        let s = magnitude_histogram("h", &[0.1, 0.2, 5.0], 4);
        assert!(s.contains("5.0"));
    }
}
