//! Baseline outlier pre-processors — the comparators of paper Table 3a:
//! OMSE (Choukroun et al. 2019), Percentile (Zhou et al. 2017),
//! Outlier Suppression (Wei et al. 2022b) and SmoothQuant (Xiao et al.
//! 2022). All are implemented as equivalent transforms / clipping on
//! [`ModelParams`], mirroring how the paper's ablation applies them before
//! the (optional) reconstruction stage.

use crate::config::qmax;
use crate::model_state::{ActStats, ModelParams};
use crate::quant::{init_scales, quant_mse, LINEARS};
use crate::tensor::Tensor;

use super::apply::{migrate_channel_scales, PreprocReport};

/// OMSE: per-linear search over clip ratios minimizing weight quantization
/// MSE at 4 bits, then clip weights to the chosen range. (Weight-only; OMSE
/// has no activation handling — exactly why it underperforms in Table 3a.)
pub fn apply_omse(params: &mut ModelParams) -> PreprocReport {
    let mut report = PreprocReport::default();
    // search at a low-bit target (3-bit) where range/resolution trade-offs
    // actually bite; the chosen clip then helps every bit-width above it.
    let qm = qmax(3);
    for b in &mut params.blocks {
        for lin in LINEARS {
            let w = b.linear(lin).clone();
            let full = init_scales(&w, qm);
            let mut best = (f32::INFINITY, 1.0f32);
            for step in 0..=16 {
                let ratio = 0.2 + 0.05 * step as f32;
                let s = full.map(|v| v * ratio);
                let e = quant_mse(&w, &s, qm);
                if e < best.0 {
                    best = (e, ratio);
                }
            }
            if best.1 < 0.999 {
                // clip weights into the chosen range
                let wt = b.linear_mut(lin);
                let caps: Vec<f32> = full.data.iter().map(|s| s * best.1 * qm).collect();
                let n = wt.cols();
                let mut clipped = 0;
                for i in 0..wt.rows() {
                    for j in 0..n {
                        let v = wt.at2(i, j);
                        if v.abs() > caps[j] {
                            wt.set2(i, j, v.signum() * caps[j]);
                            clipped += 1;
                        }
                    }
                }
                report.weights_truncated += clipped;
            }
        }
    }
    report
}

/// Percentile: clip weights at the 99.9th magnitude percentile and scale
/// activation channels above the 99.9th percentile of channel maxima.
pub fn apply_percentile(params: &mut ModelParams, stats: &ActStats) -> PreprocReport {
    let mut report = PreprocReport::default();
    const PCT: f32 = 0.999;
    for bi in 0..params.blocks.len() {
        for lin in LINEARS {
            // weights
            let w = params.blocks[bi].linear_mut(lin);
            let mut mags: Vec<f32> = w.data.iter().map(|v| v.abs()).collect();
            mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let cap = mags[((mags.len() - 1) as f32 * PCT) as usize];
            for v in w.data.iter_mut() {
                if v.abs() > cap {
                    *v = v.signum() * cap;
                    report.weights_truncated += 1;
                }
            }
            // activations: everything above the percentile of channel maxima
            // is scaled fully down to the cap (no sqrt migration — the
            // cruder handling is the point of the baseline)
            let maxima = stats.max_of(bi, lin);
            let mut sorted = maxima.to_vec();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let acap = sorted[((sorted.len() - 1) as f32 * PCT) as usize].max(1e-6);
            let scales: Vec<f32> =
                maxima.iter().map(|&m| if m > acap { m / acap } else { 1.0 }).collect();
            if scales.iter().any(|&s| s > 1.0) {
                report.channels_scaled += scales.iter().filter(|&&s| s > 1.0).count();
                migrate_channel_scales(params, bi, lin, &scales);
            }
        }
    }
    report
}

/// Outlier Suppression: migrate the *entire* norm weight gamma into the
/// consuming linears (gamma -> 1), removing the norm-amplified activation
/// outliers Wei et al. attribute to LayerNorm's gamma.
pub fn apply_os(params: &mut ModelParams) -> PreprocReport {
    let mut report = PreprocReport::default();
    for bi in 0..params.blocks.len() {
        let groups: [(&str, &[&str]); 2] =
            [("attn", &["wq", "wk", "wv"]), ("mlp", &["wgate", "wup"])];
        for (norm_key, consumers) in groups {
            let gamma = if norm_key == "attn" {
                params.blocks[bi].attn_norm.clone()
            } else {
                params.blocks[bi].mlp_norm.clone()
            };
            // scales = |gamma| (sign folded into weights too); gamma -> 1
            for consumer in consumers {
                let w = params.blocks[bi].linear_mut(consumer);
                for (i, &g) in gamma.data.iter().enumerate() {
                    w.scale_row(i, g);
                }
            }
            let norm = if norm_key == "attn" {
                &mut params.blocks[bi].attn_norm
            } else {
                &mut params.blocks[bi].mlp_norm
            };
            for v in norm.data.iter_mut() {
                *v = 1.0;
            }
            report.channels_scaled += gamma.len();
        }
    }
    report
}

/// SmoothQuant: per-channel migration `s_i = max|X_i|^a / max|W_i|^(1-a)`
/// applied to every channel of every quantized linear input.
pub fn apply_smoothquant(
    params: &mut ModelParams,
    stats: &ActStats,
    alpha: f32,
) -> PreprocReport {
    let mut report = PreprocReport::default();
    for bi in 0..params.blocks.len() {
        // group the shared-input linears so the producer is divided once
        for group in [vec!["wq", "wk", "wv"], vec!["wgate", "wup"], vec!["wo"], vec!["wdown"]] {
            let lead = group[0];
            let maxima = stats.max_of(bi, lead);
            let k = maxima.len();
            // per-input-row weight maxima across the group
            let mut wmax = vec![0.0f32; k];
            for lin in &group {
                let w = params.blocks[bi].linear(lin);
                for i in 0..k {
                    let m = w.row(i).iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                    if m > wmax[i] {
                        wmax[i] = m;
                    }
                }
            }
            let scales: Vec<f32> = maxima
                .iter()
                .zip(&wmax)
                .map(|(&xm, &wm)| {
                    let s = xm.max(1e-5).powf(alpha) / wm.max(1e-5).powf(1.0 - alpha);
                    s.clamp(0.1, 1e4)
                })
                .collect();
            report.channels_scaled += scales.iter().filter(|&&s| (s - 1.0).abs() > 1e-3).count();
            migrate_channel_scales(params, bi, lead, &scales);
        }
    }
    report
}

/// Helper shared with tests: max |W| per input row.
pub fn row_maxima(w: &Tensor) -> Vec<f32> {
    (0..w.rows())
        .map(|i| w.row(i).iter().fold(0.0f32, |a, &v| a.max(v.abs())))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_state::BlockParams;
    use std::collections::BTreeMap;

    fn params_with(f: impl Fn(&str) -> Tensor) -> ModelParams {
        let mut linears = BTreeMap::new();
        for l in LINEARS {
            linears.insert(l.to_string(), f(l));
        }
        ModelParams {
            embed: Tensor::zeros(&[8, 4]),
            final_norm: Tensor::full(&[4], 1.0),
            head: Tensor::zeros(&[4, 8]),
            blocks: vec![BlockParams {
                attn_norm: Tensor::new(vec![4], vec![1.0, 8.0, 1.0, 0.5]),
                mlp_norm: Tensor::full(&[4], 1.0),
                linears,
            }],
        }
    }

    fn shape_of(l: &str) -> (usize, usize) {
        match l {
            "wgate" | "wup" => (4, 8),
            "wdown" => (8, 4),
            _ => (4, 4),
        }
    }

    fn default_params() -> ModelParams {
        params_with(|l| {
            let (k, n) = shape_of(l);
            Tensor::new(
                vec![k, n],
                (0..k * n).map(|i| ((i % 7) as f32 - 3.0) * 0.1).collect(),
            )
        })
    }

    fn flat_stats(p: &ModelParams) -> ActStats {
        let mut st = ActStats::new(1);
        for l in LINEARS {
            let k = p.blocks[0].linears[l].rows();
            st.accumulate(0, l, &Tensor::full(&[2, k], 1.0));
        }
        st
    }

    #[test]
    fn os_normalizes_gamma() {
        let mut p = default_params();
        let wq_before = p.blocks[0].linears["wq"].clone();
        apply_os(&mut p);
        assert!(p.blocks[0].attn_norm.data.iter().all(|&v| v == 1.0));
        // row 1 scaled by old gamma 8.0
        assert!((p.blocks[0].linears["wq"].at2(1, 0) - wq_before.at2(1, 0) * 8.0).abs() < 1e-6);
    }

    #[test]
    fn smoothquant_balances_hot_channel() {
        let mut p = default_params();
        let mut st = flat_stats(&p);
        // hot activation channel 2 for the attn group
        st.channel_max[0].get_mut("wq").unwrap()[2] = 100.0;
        apply_smoothquant(&mut p, &st, 0.5);
        // norm weight channel 2 got divided (producer side)
        assert!(p.blocks[0].attn_norm.data[2] < 1.0);
    }

    #[test]
    fn omse_reduces_quant_mse_with_heavy_tail() {
        // tall matrices: clipping one heavy-tail entry per column buys
        // resolution for 63 bulk values — the regime OMSE targets
        let mut p = params_with(|_l| {
            let (k, n) = (512usize, 2usize);
            let mut d: Vec<f32> = (0..k * n)
                .map(|i| ((i * 131) % 100) as f32 / 100.0 * 4.0 - 2.0)
                .collect();
            for j in 0..n {
                d[j] = 20.0;
            }
            Tensor::new(vec![k, n], d)
        });
        let before = {
            let w = p.blocks[0].linears["wq"].clone();
            quant_mse(&w, &init_scales(&w, 3.0), 3.0)
        };
        let rep = apply_omse(&mut p);
        let w = p.blocks[0].linears["wq"].clone();
        let after = quant_mse(&w, &init_scales(&w, 3.0), 3.0);
        assert!(rep.weights_truncated > 0);
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn percentile_clips_extremes() {
        let mut p = params_with(|l| {
            let (k, n) = shape_of(l);
            let mut d: Vec<f32> = vec![0.01; k * n];
            d[1] = 50.0;
            Tensor::new(vec![k, n], d)
        });
        let st = flat_stats(&p);
        let rep = apply_percentile(&mut p, &st);
        assert!(rep.weights_truncated > 0);
        assert!(p.blocks[0].linears["wq"].data[1] < 50.0);
    }
}
