//! Model surgery: apply outlier pre-processing to [`ModelParams`] as exact
//! equivalent transforms, per method.
//!
//! Channel-scaling migration paths (all function-preserving):
//!   * `wq/wk/wv`  input = attn RMSNorm output -> fold 1/s into `attn_norm`
//!     weight, s into the linears' input rows;
//!   * `wgate/wup` input = mlp RMSNorm output -> fold via `mlp_norm`;
//!   * `wo`        input = attention value mix -> fold via `wv` columns
//!     (v-channels pass linearly through softmax mixing);
//!   * `wdown`     input = silu(gate) * up    -> fold via `wup` columns
//!     (the `up` factor is linear in the channel).

use std::collections::BTreeMap;

use crate::config::PreprocMethod;
use crate::model_state::{ActStats, ModelParams};
use crate::quant::LINEARS;

use super::{activation_scales, baselines, detect_default, truncate_weights, Detection};

/// Report of what pre-processing did (Fig. 3 + Table 3a diagnostics).
#[derive(Clone, Debug, Default)]
pub struct PreprocReport {
    /// Total weight entries clipped to their group's reserved maximum.
    pub weights_truncated: usize,
    /// Activation channels whose scaling was migrated into weights.
    pub channels_scaled: usize,
    /// per (block, linear): detection summary on weights
    pub weight_detections: Vec<(usize, String, Detection)>,
    /// per (block, linear): detection summary on activation channel maxima
    pub act_detections: Vec<(usize, String, Detection)>,
}

/// Apply `method` to the model in place. `stats` must hold calibration
/// activation statistics for every (block, linear).
pub fn apply(
    method: PreprocMethod,
    params: &mut ModelParams,
    stats: &ActStats,
    sq_alpha: f32,
) -> PreprocReport {
    match method {
        PreprocMethod::None => PreprocReport::default(),
        PreprocMethod::Omse => baselines::apply_omse(params),
        PreprocMethod::Percentile => baselines::apply_percentile(params, stats),
        PreprocMethod::OutlierSuppression => baselines::apply_os(params),
        PreprocMethod::SmoothQuant => baselines::apply_smoothquant(params, stats, sq_alpha),
        PreprocMethod::CfpActivation => apply_cfp(params, stats, false, true),
        PreprocMethod::CfpWeight => apply_cfp(params, stats, true, false),
        PreprocMethod::CfpFull => apply_cfp(params, stats, true, true),
    }
}

/// CFP proper (Sec. 3.4): weight truncation and/or activation scaling.
pub fn apply_cfp(
    params: &mut ModelParams,
    stats: &ActStats,
    weights_too: bool,
    activations_too: bool,
) -> PreprocReport {
    let mut report = PreprocReport::default();
    for bi in 0..params.blocks.len() {
        // ----- weights: detect + truncate PER OUTPUT COLUMN ---------------
        // Weight quantization is per-output-channel (one step size per
        // column), so outlier handling must match that granularity: an
        // entry is an outlier relative to *its own quantization group*.
        // Whole-matrix detection would flag uniformly-large columns whose
        // truncation buys no resolution (their scale is theirs alone) and
        // only destroys signal.
        if weights_too {
            for lin in LINEARS {
                let w = params.blocks[bi].linear_mut(lin);
                let (k, n) = (w.rows(), w.cols());
                let mut truncated = 0usize;
                let mut col = vec![0.0f32; k];
                for j in 0..n {
                    for i in 0..k {
                        col[i] = w.at2(i, j);
                    }
                    let det = detect_default(&col);
                    if det.n_outliers > 0 {
                        truncated += truncate_weights(&mut col, &det);
                        for i in 0..k {
                            w.set2(i, j, col[i]);
                        }
                    }
                    if j == 0 {
                        report.weight_detections.push((bi, lin.to_string(), det));
                    }
                }
                report.weights_truncated += truncated;
            }
        }
        // ----- activations: detect outlier channels + migrate scaling -----
        if !activations_too {
            continue;
        }
        for lin in LINEARS {
            let maxima = stats.max_of(bi, lin).to_vec();
            let det = detect_default(&maxima);
            if det.n_outliers > 0 {
                let scales = activation_scales(&maxima, &det);
                report.channels_scaled +=
                    scales.iter().filter(|&&s| (s - 1.0).abs() > 1e-6).count();
                migrate_channel_scales(params, bi, lin, &scales);
            }
            report.act_detections.push((bi, lin.to_string(), det));
        }
    }
    report
}

/// Divide activation channel `i` by `scales[i]` and compensate in weights —
/// exact equivalent transform per the module docs. Applying for a linear
/// whose input is shared (wq/wk/wv share attn_in; wgate/wup share mlp_in)
/// touches all consumers, so callers pass the same scales for the group:
/// we divide the *producer* once and multiply every consumer's rows.
pub fn migrate_channel_scales(
    params: &mut ModelParams,
    block: usize,
    linear: &str,
    scales: &[f32],
) {
    // producer division
    match linear {
        "wq" | "wk" | "wv" => {
            for (i, &s) in scales.iter().enumerate() {
                params.blocks[block].attn_norm.data[i] /= s;
            }
            for consumer in ["wq", "wk", "wv"] {
                scale_rows(params, block, consumer, scales);
            }
        }
        "wgate" | "wup" => {
            for (i, &s) in scales.iter().enumerate() {
                params.blocks[block].mlp_norm.data[i] /= s;
            }
            for consumer in ["wgate", "wup"] {
                scale_rows(params, block, consumer, scales);
            }
        }
        "wo" => {
            // v-channel: wv column /= s, wo row *= s
            for (i, &s) in scales.iter().enumerate() {
                if (s - 1.0).abs() > 1e-9 {
                    params.blocks[block].linear_mut("wv").scale_col(i, 1.0 / s);
                }
            }
            scale_rows(params, block, "wo", scales);
        }
        "wdown" => {
            for (i, &s) in scales.iter().enumerate() {
                if (s - 1.0).abs() > 1e-9 {
                    params.blocks[block].linear_mut("wup").scale_col(i, 1.0 / s);
                }
            }
            scale_rows(params, block, "wdown", scales);
        }
        other => panic!("unknown linear {other}"),
    }
}

fn scale_rows(params: &mut ModelParams, block: usize, linear: &str, scales: &[f32]) {
    let w = params.blocks[block].linear_mut(linear);
    for (i, &s) in scales.iter().enumerate() {
        if (s - 1.0).abs() > 1e-9 {
            w.scale_row(i, s);
        }
    }
}

/// Post-preprocessing activation statistics prediction: channel maxima
/// divided by the applied scales — used to re-derive stats without a second
/// capture pass for grouped consumers.
pub fn scaled_stats(stats: &ActStats, scale_map: &BTreeMap<(usize, String), Vec<f32>>) -> ActStats {
    let mut out = stats.clone();
    for ((bi, lin), scales) in scale_map {
        if let Some(v) = out.channel_max[*bi].get_mut(lin) {
            for (m, s) in v.iter_mut().zip(scales) {
                *m /= s;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_state::BlockParams;
    use crate::tensor::Tensor;

    fn tiny_params() -> ModelParams {
        let d = 4usize;
        let f = 8usize;
        let lin = |k: usize, n: usize, seed: usize| {
            Tensor::new(
                vec![k, n],
                (0..k * n).map(|i| ((i * 37 + seed) % 11) as f32 / 11.0 - 0.5).collect(),
            )
        };
        let mut linears = BTreeMap::new();
        for (i, l) in LINEARS.iter().enumerate() {
            let (fi, fo) = match *l {
                "wgate" | "wup" => (d, f),
                "wdown" => (f, d),
                _ => (d, d),
            };
            linears.insert(l.to_string(), lin(fi, fo, i));
        }
        ModelParams {
            embed: Tensor::zeros(&[16, d]),
            final_norm: Tensor::full(&[d], 1.0),
            head: Tensor::zeros(&[d, 16]),
            blocks: vec![BlockParams {
                attn_norm: Tensor::full(&[d], 1.0),
                mlp_norm: Tensor::full(&[d], 1.0),
                linears,
            }],
        }
    }

    /// Functional check: y = norm_diag(x) @ W must be invariant under the
    /// migration for the norm-fed linears.
    #[test]
    fn migration_preserves_norm_linear_product() {
        let mut p = tiny_params();
        let before_norm = p.blocks[0].attn_norm.clone();
        let before_w = p.blocks[0].linears["wq"].clone();
        let scales = vec![2.0, 1.0, 4.0, 1.0];
        migrate_channel_scales(&mut p, 0, "wq", &scales);
        // effective op on a post-norm vector a: (a/s) fed to (s*W) rows
        // == a fed to W when the norm weight absorbs 1/s.
        let a = [0.3f32, -0.7, 1.1, 0.25];
        let d = 4;
        let mut y_before = vec![0.0f32; d];
        let mut y_after = vec![0.0f32; d];
        for j in 0..d {
            for i in 0..d {
                y_before[j] += a[i] * before_norm.data[i] * before_w.at2(i, j);
                y_after[j] += a[i] * p.blocks[0].attn_norm.data[i]
                    * p.blocks[0].linears["wq"].at2(i, j);
            }
        }
        for (x, y) in y_before.iter().zip(&y_after) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn wo_migration_balances_wv() {
        let mut p = tiny_params();
        let wv0 = p.blocks[0].linears["wv"].clone();
        let wo0 = p.blocks[0].linears["wo"].clone();
        let scales = vec![3.0, 1.0, 1.0, 1.0];
        migrate_channel_scales(&mut p, 0, "wo", &scales);
        // column 0 of wv divided, row 0 of wo multiplied
        assert!((p.blocks[0].linears["wv"].at2(1, 0) - wv0.at2(1, 0) / 3.0).abs() < 1e-6);
        assert!((p.blocks[0].linears["wo"].at2(0, 2) - wo0.at2(0, 2) * 3.0).abs() < 1e-6);
        // untouched elsewhere
        assert_eq!(p.blocks[0].linears["wv"].at2(1, 1), wv0.at2(1, 1));
    }

    #[test]
    fn cfp_full_truncates_planted_weight_outlier() {
        let mut p = tiny_params();
        p.blocks[0].linear_mut("wup").data[3] = 500.0;
        let mut stats = ActStats::new(1);
        for l in LINEARS {
            let k = p.blocks[0].linears[l].rows();
            stats.accumulate(0, l, &Tensor::full(&[2, k], 0.5));
        }
        let rep = apply_cfp(&mut p, &stats, true, true);
        assert!(rep.weights_truncated >= 1);
        assert!(p.blocks[0].linears["wup"].data[3] < 500.0);
    }

    #[test]
    fn cfp_activation_scales_planted_channel() {
        let mut p = tiny_params();
        let mut stats = ActStats::new(1);
        for l in LINEARS {
            let k = p.blocks[0].linears[l].rows();
            let mut x = Tensor::full(&[8, k], 0.4);
            if l == "wq" {
                // plant a hot input channel
                for r in 0..8 {
                    x.set2(r, 2, 64.0);
                }
            }
            stats.accumulate(0, l, &x);
        }
        let norm_before = p.blocks[0].attn_norm.data[2];
        let rep = apply_cfp(&mut p, &stats, false, true);
        assert!(rep.channels_scaled >= 1);
        assert!(p.blocks[0].attn_norm.data[2] < norm_before);
    }
}
