//! CFP — coarse-to-fine pre-processing (paper Sec. 3.4 + Appendix F/K).
//!
//! Distribution-free outlier detection in two stages (Algorithm 1):
//!   1. coarse: quartile criterion `T = Q3 + lambda1 * IQR` over |x| — cheap,
//!      assumption-free candidate set;
//!   2. fine: scan split points of the sorted candidate set maximizing
//!      `M = M_inter - lambda2 * M_intra` where `M_inter` is the squared gap
//!      between reserved and outlier subsets and `M_intra = Var(O_reserved)`.
//!
//! Downstream handling (Sec. 3.4):
//!   * weights   -> truncate outliers to the reserved maximum;
//!   * activations -> per-channel scaling `s_i = sqrt(max|X_i| / max O*)`
//!     migrated into adjacent weights as an exact equivalent transform
//!     (see [`apply`]).

pub mod apply;
pub mod baselines;

/// Paper-default coarse-stage IQR factor (lambda1 in Algorithm 1).
pub const LAMBDA1: f32 = 1.5;
/// Paper-default fine-stage intra-class variance weight (lambda2).
pub const LAMBDA2: f32 = 1.0;

/// Result of outlier detection over a set of magnitudes.
#[derive(Clone, Debug, PartialEq)]
pub struct Detection {
    /// Values >= this are outliers (min of the outlier subset). `None` if
    /// no outliers were detected.
    pub threshold: Option<f32>,
    /// Truncation level: maximum of the reserved (non-outlier) data.
    pub reserved_max: f32,
    /// Number of detected outliers.
    pub n_outliers: usize,
    /// Coarse-stage candidate count (before the fine split).
    pub n_candidates: usize,
}

impl Detection {
    /// Is `v` past the detected threshold (by magnitude)? Always `false`
    /// when detection found no outliers.
    pub fn is_outlier(&self, v: f32) -> bool {
        match self.threshold {
            Some(t) => v.abs() >= t,
            None => false,
        }
    }
}

/// Algorithm 1 over the magnitudes of `values`.
pub fn detect(values: &[f32], lambda1: f32, lambda2: f32) -> Detection {
    let mut mags: Vec<f32> = values.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = mags.len();
    if n < 4 {
        return Detection {
            threshold: None,
            reserved_max: mags.last().copied().unwrap_or(0.0),
            n_outliers: 0,
            n_candidates: 0,
        };
    }
    // --- coarse: quartile criterion --------------------------------------
    // (n-1)-based quantile indices so small sets (e.g. per-channel maxima
    // of narrow layers) don't land Q3 on the extreme value itself
    let q1 = mags[(n - 1) / 4];
    let q3 = mags[3 * (n - 1) / 4];
    let iqr = q3 - q1;
    let t = q3 + lambda1 * iqr;
    let first = mags.partition_point(|&v| v <= t);
    let candidates = &mags[first..];
    let below_max = if first == 0 { 0.0 } else { mags[first - 1] };
    if candidates.len() < 2 {
        // 0 or 1 candidate: a single extreme point is an outlier iff it is
        // clearly separated from the bulk (gap > its own IQR distance).
        if candidates.len() == 1 {
            return Detection {
                threshold: Some(candidates[0]),
                reserved_max: below_max,
                n_outliers: 1,
                n_candidates: 1,
            };
        }
        return Detection {
            threshold: None,
            reserved_max: below_max.max(mags[n - 1].min(t)),
            n_outliers: 0,
            n_candidates: 0,
        };
    }
    // --- fine: maximize M = M_inter - lambda2 * M_intra -------------------
    // Split i: O_outlier = candidates[i..], O_reserved = bulk + candidates[..i]
    // (Algorithm 1 iterates i = 0..N; the reserved subset rejoins the
    // non-candidate bulk, so its variance is computed over everything kept).
    let m_c = candidates.len();
    let bulk = &mags[..first];
    let (mut rs, mut rq) = bulk
        .iter()
        .fold((0.0f64, 0.0f64), |(s, q), &v| (s + v as f64, q + (v * v) as f64));
    let mut rn = bulk.len() as f64;
    let mut best_m = f32::NEG_INFINITY;
    let mut best_i = m_c; // default: nothing declared outlier
    for i in 0..m_c {
        let var = if rn > 0.0 {
            let mean = rs / rn;
            (rq / rn - mean * mean).max(0.0) as f32
        } else {
            0.0
        };
        let reserved_max = if i > 0 { candidates[i - 1] } else { below_max };
        let gap = candidates[i] - reserved_max;
        let m = gap * gap - lambda2 * var;
        if m > best_m {
            best_m = m;
            best_i = i;
        }
        // candidate i joins the reserved set for the next split
        rs += candidates[i] as f64;
        rq += (candidates[i] * candidates[i]) as f64;
        rn += 1.0;
    }
    // accept only if the inter-class separation beats the intra-class
    // variance (M > 0) — a smooth tail yields no outliers.
    let (threshold, reserved_max, n_outliers) = if best_i == m_c || best_m <= 0.0 {
        (None, candidates[m_c - 1], 0)
    } else {
        let rmax = if best_i > 0 { candidates[best_i - 1] } else { below_max };
        (Some(candidates[best_i]), rmax, m_c - best_i)
    };
    Detection { threshold, reserved_max, n_outliers, n_candidates: m_c }
}

/// Detect with the paper's default lambdas.
pub fn detect_default(values: &[f32]) -> Detection {
    detect(values, LAMBDA1, LAMBDA2)
}

/// Truncate weight outliers in place: `|w| > reserved_max` clipped to
/// `sign(w) * reserved_max` (Sec. 3.4: "truncating weight outliers").
pub fn truncate_weights(data: &mut [f32], det: &Detection) -> usize {
    let Some(_t) = det.threshold else { return 0 };
    let cap = det.reserved_max;
    let mut n = 0;
    for v in data.iter_mut() {
        if det.is_outlier(*v) {
            *v = v.signum() * cap;
            n += 1;
        }
    }
    n
}

/// Per-channel activation scaling factors (Eq. 14): outlier channels get
/// `s_i = sqrt(max|X_i| / max O*)` (> 1), others 1.0. `channel_maxima` are
/// the per-channel max |X_i| statistics from calibration capture.
pub fn activation_scales(channel_maxima: &[f32], det: &Detection) -> Vec<f32> {
    let t_star = det.reserved_max.max(crate::quant::EPS);
    channel_maxima
        .iter()
        .map(|&m| {
            if det.is_outlier(m) && m > t_star {
                (m / t_star).sqrt()
            } else {
                1.0
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bulk_plus_outliers(n: usize, outliers: &[f32]) -> Vec<f32> {
        // deterministic bulk in [-1, 1]
        let mut v: Vec<f32> =
            (0..n).map(|i| ((i * 2654435761) % 2000) as f32 / 1000.0 - 1.0).collect();
        v.extend_from_slice(outliers);
        v
    }

    #[test]
    fn detects_clear_outliers() {
        let data = bulk_plus_outliers(1000, &[25.0, -30.0, 28.0]);
        let det = detect_default(&data);
        assert_eq!(det.n_outliers, 3);
        assert!(det.threshold.unwrap() > 1.0);
        assert!(det.reserved_max <= 1.0 + 1e-6);
    }

    #[test]
    fn no_outliers_in_uniform_bulk() {
        let data = bulk_plus_outliers(1000, &[]);
        let det = detect_default(&data);
        assert_eq!(det.n_outliers, 0);
        assert!(det.threshold.is_none());
    }

    #[test]
    fn fine_stage_rejects_smooth_tail() {
        // heavy but *smooth* tail: coarse flags candidates, the fine split
        // finds no strong gap and (gap^2 - var) peaks at the true break.
        let mut data = bulk_plus_outliers(500, &[]);
        data.extend((0..50).map(|i| 1.0 + i as f32 * 0.01)); // smooth ramp
        data.push(50.0); // one real outlier
        let det = detect_default(&data);
        assert_eq!(det.n_outliers, 1);
        assert!(det.threshold.unwrap() > 10.0);
    }

    #[test]
    fn truncation_caps_only_outliers() {
        let mut data = bulk_plus_outliers(800, &[40.0, -44.0]);
        let det = detect_default(&data);
        let n = truncate_weights(&mut data, &det);
        assert_eq!(n, 2);
        let mx = data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        assert!(mx <= det.reserved_max + 1e-6);
        // signs preserved
        assert!(data[801] < 0.0);
    }

    #[test]
    fn activation_scales_selective() {
        let maxima = vec![1.0, 1.2, 0.9, 30.0, 1.1, 26.0];
        let det = detect_default(&bulk_plus_outliers(500, &[30.0, 26.0]));
        let s = activation_scales(&maxima, &det);
        assert_eq!(s[0], 1.0);
        assert!(s[3] > 3.0 && s[3] < 8.0);
        assert!(s[5] > 3.0);
        // sqrt migration: scaled channel max becomes sqrt(m * t*)
        let migrated = maxima[3] / s[3];
        assert!(migrated < maxima[3] && migrated > det.reserved_max * 0.9);
    }

    #[test]
    fn small_input_safe() {
        let det = detect_default(&[1.0, 2.0]);
        assert!(det.threshold.is_none());
        let det = detect_default(&[]);
        assert_eq!(det.reserved_max, 0.0);
    }

    #[test]
    fn single_extreme_candidate() {
        let data = bulk_plus_outliers(1000, &[100.0]);
        let det = detect_default(&data);
        assert_eq!(det.n_outliers, 1);
        assert!(det.is_outlier(100.0));
        assert!(!det.is_outlier(0.5));
    }
}
