//! `cbq` — the CBQ quantization launcher.
//!
//! Subcommands:
//!   quantize  run a full PTQ job (method x bits x preproc x CBD config)
//!             and report perplexity vs the FP model
//!   eval      evaluate the FP model (sanity baseline)
//!   zeroshot  quantize then run the zero-shot task suite
//!   hessian   finite-difference dependency analysis (paper Fig. 1)
//!   info      print the artifact manifest summary
//!
//! Flag parsing is hand-rolled (`cbq::cli`) — the build environment vendors
//! only the xla crate's dependency closure, so no clap.

use anyhow::{bail, Result};

use cbq::calib::corpus::Style;
use cbq::cli::Args;
use cbq::config::{BitSpec, PreprocMethod, QuantJob, RoundingMode};
use cbq::coordinator::Pipeline;
use cbq::hessian::{offdiag_ratio, HessianProbe};
use cbq::report::{fmt_f, heatmap, Table};
use cbq::runtime::{Artifacts, Runtime};

const USAGE: &str = "\
cbq — Cross-Block Quantization for LLMs (ICLR 2025 reproduction)

USAGE: cbq [--artifacts DIR] <COMMAND> [flags]

COMMANDS
  info                         artifact manifest summary
  eval      --model s          FP perplexity baseline
  quantize  --model s --method cbq --w 4 --a 16 [--star]
            --preproc cfp|none|omse|percentile|os|smoothquant|cfp-act
            --window 2 --overlap 1 --epochs 3 --rank 5
            --calib 32 --eval-batches 16
  zeroshot  --model s --method cbq --w 4 --a 16 --items 32 --calib 32
  hessian   --model t --bits 8,4,2
";

fn parse_method(args: &Args, bits: BitSpec) -> Result<QuantJob> {
    Ok(match args.get("method").unwrap_or("cbq") {
        "rtn" => QuantJob::rtn(bits),
        "gptq" => QuantJob::gptq(bits),
        "cbq" => QuantJob::cbq(bits),
        "omniquant" => QuantJob::omniquant_like(bits),
        m => bail!("unknown method `{m}`"),
    })
}

fn parse_preproc(s: &str) -> Result<PreprocMethod> {
    Ok(match s {
        "none" => PreprocMethod::None,
        "omse" => PreprocMethod::Omse,
        "percentile" => PreprocMethod::Percentile,
        "os" => PreprocMethod::OutlierSuppression,
        "smoothquant" => PreprocMethod::SmoothQuant,
        "cfp-act" => PreprocMethod::CfpActivation,
        "cfp" => PreprocMethod::CfpFull,
        p => bail!("unknown preproc `{p}`"),
    })
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let Some(cmd) = args.command() else {
        print!("{USAGE}");
        return Ok(());
    };
    let art = match args.get("artifacts") {
        Some(p) => Artifacts::load(p)?,
        None => Artifacts::discover()?,
    };
    let rt = Runtime::new(&art)?;

    match cmd {
        "info" => {
            println!("artifacts: {:?}", art.dir);
            let mut t =
                Table::new("configs", &["name", "d_model", "layers", "heads", "ffn", "windows"]);
            for (name, c) in &art.manifest.configs {
                t.row(&[
                    name.clone(),
                    c.d_model.to_string(),
                    c.n_layers.to_string(),
                    c.n_heads.to_string(),
                    c.d_ffn.to_string(),
                    format!("{:?}", art.manifest.windows.get(name).cloned().unwrap_or_default()),
                ]);
            }
            t.print();
            println!("\n{} executables", art.manifest.executables.len());
        }
        "eval" => {
            let model = args.get("model").unwrap_or("s");
            let n = args.get_usize("eval-batches", 16)?;
            let pipe = Pipeline::new(&art, &rt, model)?;
            let fp = pipe.fp_model();
            let c4 = pipe.perplexity(&fp, Style::C4, n)?;
            let wiki = pipe.perplexity(&fp, Style::Wiki, n)?;
            println!("FP {model}: ppl(c4) = {c4:.3}, ppl(wiki) = {wiki:.3}");
        }
        "quantize" => {
            let model = args.get("model").unwrap_or("s");
            let mut pipe = Pipeline::new(&art, &rt, model)?;
            let n_layers = pipe.cfg.n_layers;
            let bits = if args.flag("star") {
                BitSpec::w2a16_star(n_layers)
            } else {
                BitSpec::new(args.get_usize("w", 4)? as u8, args.get_usize("a", 16)? as u8)
            };
            let mut job = parse_method(&args, bits)?;
            if let Some(p) = args.get("preproc") {
                job.preproc = parse_preproc(p)?;
            }
            job.window = args.get_usize("window", job.window)?;
            job.overlap = args.get_usize("overlap", job.overlap)?;
            job.epochs = args.get_usize("epochs", job.epochs)?;
            job.calib_sequences = args.get_usize("calib", 32)?;
            let rank = args.get_usize("rank", job.rank)?;
            if rank == 0 {
                job.rounding = RoundingMode::Nearest;
            } else {
                job.rank = rank;
            }
            let eval_batches = args.get_usize("eval-batches", 16)?;
            println!("running {} on model {model}...", job.label());
            let (qm, summary) = pipe.run(&job)?;
            let fp = pipe.fp_model();
            let mut t = Table::new(
                format!("{} (quantized in {:.1}s)", job.label(), summary.quant_seconds),
                &["model", "ppl c4", "ppl wiki"],
            );
            let c4 = pipe.perplexity(&qm, Style::C4, eval_batches)?;
            let wiki = pipe.perplexity(&qm, Style::Wiki, eval_batches)?;
            let fc4 = pipe.perplexity(&fp, Style::C4, eval_batches)?;
            let fwiki = pipe.perplexity(&fp, Style::Wiki, eval_batches)?;
            t.row(&["FP".into(), fmt_f(fc4, 3), fmt_f(fwiki, 3)]);
            t.row(&[job.label(), fmt_f(c4, 3), fmt_f(wiki, 3)]);
            t.print();
            if !summary.window_losses.is_empty() {
                println!("window losses: {:?}", summary.window_losses);
            }
            let stats = rt.stats();
            println!(
                "runtime: {} executions, {:.1}ms exec, {:.1}ms compile",
                stats.executions, stats.execute_ms, stats.compile_ms
            );
        }
        "zeroshot" => {
            let model = args.get("model").unwrap_or("s");
            let mut pipe = Pipeline::new(&art, &rt, model)?;
            let bits =
                BitSpec::new(args.get_usize("w", 4)? as u8, args.get_usize("a", 16)? as u8);
            let mut job = parse_method(&args, bits)?;
            job.calib_sequences = args.get_usize("calib", 32)?;
            let items = args.get_usize("items", 32)?;
            let (qm, _) = pipe.run(&job)?;
            let fp = pipe.fp_model();
            let rq = pipe.zero_shot(&qm, items)?;
            let rf = pipe.zero_shot(&fp, items)?;
            let mut t = Table::new("zero-shot accuracy", &["task", "FP", &job.label()]);
            for (k, v) in &rf.accuracy {
                t.row(&[k.clone(), fmt_f(*v * 100.0, 2), fmt_f(rq.accuracy[k] * 100.0, 2)]);
            }
            t.row(&[
                "Mutual MRR/R@1/R@2".into(),
                format!(
                    "{}/{}/{}",
                    fmt_f(rf.mrr * 100.0, 1),
                    fmt_f(rf.recall1 * 100.0, 1),
                    fmt_f(rf.recall2 * 100.0, 1)
                ),
                format!(
                    "{}/{}/{}",
                    fmt_f(rq.mrr * 100.0, 1),
                    fmt_f(rq.recall1 * 100.0, 1),
                    fmt_f(rq.recall2 * 100.0, 1)
                ),
            ]);
            t.print();
        }
        "hessian" => {
            let model = args.get("model").unwrap_or("t");
            let pipe = Pipeline::new(&art, &rt, model)?;
            for b in args.get("bits").unwrap_or("8,4,2").split(',') {
                let wb: u8 = b.trim().parse()?;
                let probe = HessianProbe::new(&pipe, BitSpec::new(wb, 16))?;
                let h = probe.inter_block_hessian(0.05)?;
                println!("{}", heatmap(&format!("inter-block scale Hessian, W{wb}"), &h));
                println!("off-diagonal mass ratio @ W{wb}: {:.4}", offdiag_ratio(&h));
            }
        }
        other => {
            print!("{USAGE}");
            bail!("unknown command `{other}`");
        }
    }
    Ok(())
}
