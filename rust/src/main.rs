//! `cbq` — the CBQ quantization launcher.
//!
//! Subcommands:
//!   synth       generate synthetic artifacts (manifest + host-pretrained
//!               weights + corpus reference) so everything below runs
//!               end-to-end offline on the native backend
//!   quantize    run a full PTQ job (method x bits x preproc x CBD config)
//!               and report perplexity vs the FP model
//!   export      quantize, then persist the model as a CBQS snapshot
//!               (true-bit-width packed codes + quant state)
//!   load-eval   load a CBQS snapshot and evaluate it (bit-exact vs the
//!               in-memory pipeline that produced it)
//!   snapshot-info  inspect a CBQS file: header, per-tensor bit widths,
//!               packed sizes, checksum + fingerprint status
//!   serve-bench batched serving benchmark over a snapshot: coalesced vs
//!               one-by-one dispatch, tokens/s + batch occupancy
//!   eval        evaluate the FP model (sanity baseline)
//!   zeroshot    quantize then run the zero-shot task suite
//!   hessian     finite-difference dependency analysis (paper Fig. 1)
//!   info        print the artifact manifest summary
//!   fuzz        seeded adversarial harness: CBQS-container / trace-ingestion
//!               fuzzing + engine/SIMD differential oracles, deterministic
//!               per seed, nonzero exit on findings
//!
//! Execution backend: `--backend native|pjrt|auto` (or `CBQ_BACKEND`).
//! `native` interprets the manifest semantics on the host CPU — no HLO
//! artifacts or PJRT plugin needed; `pjrt` compiles the AOT HLO; `auto`
//! (default) prefers PJRT when a real client comes up.
//!
//! Flag parsing is hand-rolled (`cbq::cli`) — the build environment vendors
//! only the xla crate's dependency closure, so no clap. Both `--key value`
//! and `--key=value` work.

use anyhow::{anyhow, bail, Result};

use cbq::calib::corpus::Style;
use cbq::cli::Args;
use cbq::config::{BitSpec, PreprocMethod, QuantJob, RoundingMode};
use cbq::coordinator::Pipeline;
use cbq::hessian::{offdiag_ratio, HessianProbe};
use cbq::json::{self, Value};
use cbq::report::{fmt_bytes, fmt_f, heatmap, Table};
use cbq::runtime::{self, synth, Artifacts, Backend};
use cbq::serve::{
    batcher, Batcher, ClassLat, EngineOptions, LoadMode, ModelRegistry, RowExecutor, ServeEngine,
    ServeMetrics, ServeStats,
};
use cbq::snapshot;

const USAGE: &str = "\
cbq — Cross-Block Quantization for LLMs (ICLR 2025 reproduction)

USAGE: cbq [--artifacts DIR] [--backend native|pjrt|auto] <COMMAND> [flags]
       (flags accept both `--key value` and `--key=value`;
        CBQ_BACKEND selects the backend when --backend is absent)

COMMANDS
  synth     --out artifacts [--steps 400] [--seed 7]
            generate synthetic artifacts: tiny manifest + weights pretrained
            on-host + corpus reference — the whole pipeline then runs
            offline via `--backend native` (no JAX, no PJRT)
  info                         artifact manifest summary
  eval      --model s          FP perplexity baseline
  quantize  --model s --method cbq --w 4 --a 16 [--star]
            --preproc cfp|none|omse|percentile|os|smoothquant|cfp-act
            --window 2 --overlap 1 --epochs 3 --rank 5
            --calib 32 --eval-batches 16
  export    quantize + persist a CBQS snapshot (packed low-bit codes,
            scales, LoRA offsets, activation clips, config fingerprint,
            checksum). Same flags as quantize, plus:
            --out snap.cbqs      output path (default <model>_<label>.cbqs)
            --eval-batches 8     also record in-memory perplexity
            --json report.json   machine-readable export report
  load-eval --snapshot snap.cbqs [--eval-batches 16] [--json out.json]
            load a snapshot, verify fingerprint + checksum, evaluate
            perplexity (bit-exact vs the in-memory pipeline)
  snapshot-info --snapshot snap.cbqs [--json out.json]
            header, per-tensor bit widths + packed sizes + file offsets,
            checksum status, fingerprint check against the artifacts config
            when available, and resident-vs-mapped byte accounting
            (unpacked / eager-resident / per-block estimates for sizing
            CBQ_RESIDENT_MB, plus the packed-domain figures --packed
            serving keeps resident: codes+scales per block — since packed
            decode is the generate default, those same figures size the
            --generate working set too)
  serve-bench --snapshot snap.cbqs [--ppl-requests 32]
            [--choice-requests 8] [--hidden-requests 8] [--queue-cap 0]
            [--dispatch 1] [--json out.json]
            batched vs one-by-one serving throughput over a request mix;
            --queue-cap bounds the admission queue in rows (0 = unlimited,
            overflow requests are rejected and counted); --dispatch N
            executes up to N window batches concurrently (CBQ_THREADS
            sizes the shared kernel worker pool)
            mmap mode: --mmap [--resident-windows N] [--packed|--no-packed]
            memory-map the snapshot instead of decoding it up front:
            windows are pinned on first touch and an LRU keeps at most N
            windows (or CBQ_RESIDENT_MB bytes) resident — models larger
            than RAM serve window-by-window. On the native backend windows
            default to packed-domain pinning (codes + scales served in
            place by the quantized matmul, 4-16x smaller than f32;
            --no-packed or CBQ_PACKED=0 reverts to dequantized pinning),
            and the next planned window's file pages prefetch in the
            background while the current window executes. The one-by-one
            reference then runs on a separate eager engine, so "responses
            identical" doubles as the mmap==eager (and packed==f32)
            bitwise gate; residency (faults/hits/evictions, prefetches,
            peak bytes) is reported
            live mode: --live [--arrival-rate 256] [--trace-seed 7]
            [--trace-requests 64] [--priorities] [--real-clock]
            [--verify-determinism]
            replays a seeded synthetic arrival trace through the priority
            scheduler: interactive/batch/background classes with weighted
            aging (no starvation), admission capacity re-credited per
            drain cycle (--queue-cap now bounds rows *currently waiting*).
            The default simulated clock keeps wall time out of every
            decision, so the same seed replays bitwise-identically for any
            --dispatch; reports per-class p50/p95/p99 queue+service
            latency. --verify-determinism replays at a second lane count
            and fails on any divergence
            generate mode: --generate [--max-new-tokens 8]
            [--gen-requests 16] [--arrival-rate 256] [--trace-seed 7]
            [--slots 4] [--queue-cap 0] [--dispatch N] [--real-clock]
            [--verify-determinism]
            token generation over the KV-cached decode path (native
            backend) with continuous batching: requests join and leave the
            running decode batch per token step, scheduled by the same
            priority classes + weighted aging as --live. Greedy streams are
            always checked against a one-request-at-a-time reference;
            reports per-token p50/p95/p99 latency and decode tokens/s.
            --verify-determinism additionally replays the trace at a
            second lane count under the simulated clock. On the native
            backend generation defaults to mmap-lazy *packed* windows:
            each per-position matvec runs straight from the 2/4/8-bit
            codes (qmatvec; SIMD tier auto-probed, CBQ_SIMD=
            scalar|sse2|avx2 forces one, all tiers bitwise-equal), with
            the next window prefetching in the background. --no-packed /
            CBQ_PACKED=0 reverts to eager f32 decode — token streams are
            bitwise-identical either way
            observability (all serve-bench modes): --metrics-json out.json
            [--metrics-interval 100] [--slo-p99-ms MS]
            an always-on stats layer (atomic counters + per-class
            latency histograms in clock ticks) records every run;
            --metrics-json dumps it as a `cbq-metrics-v1` document:
            bucket bounds, periodic snapshots every --metrics-interval
            ms (live mode; default 100) plus a final one, and the alert
            log (queue_stale, occupancy_collapse, eviction_thrash,
            slo_shed, slo_recover — also streamed to stderr as JSON
            lines the moment they fire). --slo-p99-ms (live mode) arms
            the SLO controller: while the Interactive end-to-end p99
            exceeds the target, Background arrivals are shed (counted
            apart from rejected) and pending Background stops aging;
            recovery requires consecutive healthy windows (hysteresis).
            Under the simulated clock the whole shed/recover/alert
            sequence replays bitwise-identically for any --dispatch
  zeroshot  --model s --method cbq --w 4 --a 16 --items 32 --calib 32
  hessian   --model t --bits 8,4,2
  fuzz      --target snapshot|trace|differential [--seed 7] [--iters 500]
            [--fixtures DIR] [--json out.json]
            seeded structure-aware adversarial harness (needs no
            artifacts): mutates real CBQS containers / serve traces and
            runs engine + SIMD-tier differential oracles. Fully
            deterministic — equal seed/iters reprint the identical digest,
            so CI runs every target twice and compares. Exits nonzero on
            any finding; --fixtures persists minimized repro files that
            tests/fuzz_regressions.rs replays forever (docs/TESTING.md)
";

fn parse_method(args: &Args, bits: BitSpec) -> Result<QuantJob> {
    Ok(match args.get("method").unwrap_or("cbq") {
        "rtn" => QuantJob::rtn(bits),
        "gptq" => QuantJob::gptq(bits),
        "cbq" => QuantJob::cbq(bits),
        "omniquant" => QuantJob::omniquant_like(bits),
        m => bail!("unknown method `{m}`"),
    })
}

fn parse_preproc(s: &str) -> Result<PreprocMethod> {
    Ok(match s {
        "none" => PreprocMethod::None,
        "omse" => PreprocMethod::Omse,
        "percentile" => PreprocMethod::Percentile,
        "os" => PreprocMethod::OutlierSuppression,
        "smoothquant" => PreprocMethod::SmoothQuant,
        "cfp-act" => PreprocMethod::CfpActivation,
        "cfp" => PreprocMethod::CfpFull,
        p => bail!("unknown preproc `{p}`"),
    })
}

/// Shared job construction for `quantize` and `export`.
fn build_job(args: &Args, n_layers: usize) -> Result<QuantJob> {
    let bits = if args.flag("star") {
        BitSpec::w2a16_star(n_layers)
    } else {
        BitSpec::new(args.get_usize("w", 4)? as u8, args.get_usize("a", 16)? as u8)
    };
    let mut job = parse_method(args, bits)?;
    if let Some(p) = args.get("preproc") {
        job.preproc = parse_preproc(p)?;
    }
    job.window = args.get_usize("window", job.window)?;
    job.overlap = args.get_usize("overlap", job.overlap)?;
    job.epochs = args.get_usize("epochs", job.epochs)?;
    job.calib_sequences = args.get_usize("calib", 32)?;
    let rank = args.get_usize("rank", job.rank)?;
    if rank == 0 {
        job.rounding = RoundingMode::Nearest;
    } else {
        job.rank = rank;
    }
    Ok(job)
}

fn write_json(args: &Args, doc: &Value) -> Result<()> {
    if let Some(path) = args.get("json") {
        std::fs::write(path, json::dump(doc))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn serve_stats_row(t: &mut Table, mode: &str, s: &ServeStats) {
    t.row(&[
        mode.into(),
        s.dispatches.to_string(),
        format!("{:.1}%", s.occupancy() * 100.0),
        fmt_f(s.tokens_per_s(), 0),
        fmt_f(s.requests_per_s(), 1),
        s.rejected.to_string(),
        format!("{}/{}", s.peak_in_flight, s.dispatch_lanes),
        format!("{:.0}%", s.lane_occupancy() * 100.0),
        format!("{:.2}s", s.wall_seconds),
    ]);
}

fn serve_stats_json(s: &ServeStats) -> Value {
    Value::obj(vec![
        ("requests", Value::num(s.requests as f64)),
        ("dispatches", Value::num(s.dispatches as f64)),
        ("rows", Value::num(s.rows as f64)),
        ("tokens", Value::num(s.tokens as f64)),
        ("occupancy", Value::num(s.occupancy())),
        ("tokens_per_s", Value::num(s.tokens_per_s())),
        ("requests_per_s", Value::num(s.requests_per_s())),
        ("rejected", Value::num(s.rejected as f64)),
        ("shed", Value::num(s.shed as f64)),
        ("wall_seconds", Value::num(s.wall_seconds)),
        ("dispatch_lanes", Value::num(s.dispatch_lanes as f64)),
        ("peak_in_flight", Value::num(s.peak_in_flight as f64)),
        ("lane_busy_seconds", Value::num(s.lane_busy_seconds)),
        ("lane_occupancy", Value::num(s.lane_occupancy())),
        ("class_lat", Value::arr(s.class_lat.iter().map(class_lat_json).collect())),
    ])
}

fn class_lat_json(c: &ClassLat) -> Value {
    Value::obj(vec![
        ("class", Value::str(c.class.clone())),
        ("submitted", Value::num(c.submitted as f64)),
        ("completed", Value::num(c.completed as f64)),
        ("rejected", Value::num(c.rejected as f64)),
        ("queue_p50_s", Value::num(c.queue_p50_s)),
        ("queue_p95_s", Value::num(c.queue_p95_s)),
        ("queue_p99_s", Value::num(c.queue_p99_s)),
        ("service_p50_s", Value::num(c.service_p50_s)),
        ("service_p95_s", Value::num(c.service_p95_s)),
        ("service_p99_s", Value::num(c.service_p99_s)),
    ])
}

/// JSON-lines alert delivery on stderr: one object per alert, written the
/// moment the condition fires (the in-memory log keeps them too).
struct StderrAlerts;

impl cbq::serve::AlertSink for StderrAlerts {
    fn emit(&self, a: &cbq::serve::Alert) {
        eprintln!(
            "{}",
            json::dump(&Value::obj(vec![
                ("alert", Value::str(a.kind.name())),
                ("at_ticks", Value::num(a.at_ticks as f64)),
                ("detail", Value::str(a.detail.clone())),
            ]))
        );
    }
}

/// The gauge fields of a sampled [`cbq::serve::ResidencyStats`], as they
/// appear inside a metrics snapshot.
fn residency_stats_json(r: &cbq::serve::ResidencyStats) -> Value {
    Value::obj(vec![
        ("resident_windows", Value::num(r.resident_windows as f64)),
        ("resident_bytes", Value::num(r.resident_bytes as f64)),
        ("peak_windows", Value::num(r.peak_windows as f64)),
        ("peak_bytes", Value::num(r.peak_bytes as f64)),
        ("faults", Value::num(r.faults as f64)),
        ("hits", Value::num(r.hits as f64)),
        ("evictions", Value::num(r.evictions as f64)),
        ("prefetches", Value::num(r.prefetches as f64)),
        ("prefetch_hits", Value::num(r.prefetch_hits as f64)),
    ])
}

fn class_hist_json(c: &cbq::serve::ClassHist) -> Value {
    let hist = |counts: &[u64], p50: u64, p99: u64| {
        Value::obj(vec![
            ("counts", Value::arr(counts.iter().map(|&n| Value::num(n as f64)).collect())),
            ("p50_ticks", Value::num(p50 as f64)),
            ("p99_ticks", Value::num(p99 as f64)),
        ])
    };
    Value::obj(vec![
        ("class", Value::str(c.class)),
        ("queue", hist(&c.queue_counts, c.queue_p50_ticks, c.queue_p99_ticks)),
        ("service", hist(&c.service_counts, c.service_p50_ticks, c.service_p99_ticks)),
        ("latency", hist(&c.latency_counts, c.latency_p50_ticks, c.latency_p99_ticks)),
    ])
}

fn metrics_snapshot_json(s: &cbq::serve::MetricsSnapshot) -> Value {
    Value::obj(vec![
        ("at_ticks", Value::num(s.at_ticks as f64)),
        (
            "counters",
            Value::obj(vec![
                ("offered", Value::num(s.offered as f64)),
                ("admitted", Value::num(s.admitted as f64)),
                ("rejected", Value::num(s.rejected as f64)),
                ("shed", Value::num(s.shed as f64)),
                ("dispatches", Value::num(s.dispatches as f64)),
                ("tokens", Value::num(s.tokens as f64)),
                ("cycles", Value::num(s.cycles as f64)),
            ]),
        ),
        (
            "gauges",
            match &s.residency {
                Some(r) => residency_stats_json(r),
                None => Value::Null,
            },
        ),
        ("classes", Value::arr(s.classes.iter().map(class_hist_json).collect())),
        ("alerts", Value::num(s.alerts as f64)),
    ])
}

/// The `cbq-metrics-v1` document `--metrics-json` writes: histogram bucket
/// bounds (shared by every class), the SLO configuration, all snapshots in
/// emission order and the full alert log. The top bucket bound is
/// `u64::MAX` and serializes lossily through f64 — consumers should treat
/// the last bound as "+inf".
fn metrics_json_doc(m: &ServeMetrics, slo_ticks: Option<u64>) -> Value {
    Value::obj(vec![
        ("schema", Value::str("cbq-metrics-v1")),
        (
            "bucket_bounds_ticks",
            Value::arr(
                cbq::serve::metrics::bucket_bounds()
                    .iter()
                    .map(|&b| Value::num(b as f64))
                    .collect(),
            ),
        ),
        (
            "slo",
            Value::obj(vec![
                ("active", Value::Bool(slo_ticks.is_some())),
                (
                    "p99_target_ticks",
                    slo_ticks.map(|t| Value::num(t as f64)).unwrap_or(Value::Null),
                ),
            ]),
        ),
        ("snapshots", Value::arr(m.snapshots().iter().map(metrics_snapshot_json).collect())),
        (
            "alerts",
            Value::arr(
                m.alerts()
                    .iter()
                    .map(|a| {
                        Value::obj(vec![
                            ("kind", Value::str(a.kind.name())),
                            ("at_ticks", Value::num(a.at_ticks as f64)),
                            ("detail", Value::str(a.detail.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Shared `--metrics-json` epilogue: push the final snapshot at `at_ticks`,
/// dump the document, confirm on stdout. A `None` path is a no-op.
fn write_metrics_json(
    path: Option<&str>,
    m: &ServeMetrics,
    slo_ticks: Option<u64>,
    at_ticks: u64,
) -> Result<()> {
    let Some(path) = path else { return Ok(()) };
    m.push_snapshot(at_ticks);
    std::fs::write(path, json::dump(&metrics_json_doc(m, slo_ticks)))?;
    println!(
        "wrote metrics to {path} ({} snapshots, {} alerts)",
        m.snapshots().len(),
        m.alerts().len()
    );
    Ok(())
}

/// `--slo-p99-ms` / `--metrics-json` / `--metrics-interval`, shared by the
/// serve-bench modes. Returns `(slo_p99_ticks, metrics_path,
/// metrics_interval_ticks)`; the SLO controller and periodic snapshots
/// stay off unless their flags are present.
fn metrics_args(args: &Args) -> Result<(Option<u64>, Option<&str>, Option<u64>)> {
    use cbq::serve::TICKS_PER_SEC;
    let slo_ticks = match args.get("slo-p99-ms") {
        Some(_) => {
            let ms = args.get_f64("slo-p99-ms", 0.0)?;
            anyhow::ensure!(ms > 0.0, "--slo-p99-ms must be > 0 milliseconds");
            Some((((ms / 1e3) * TICKS_PER_SEC as f64) as u64).max(1))
        }
        None => None,
    };
    let metrics_path = args.get("metrics-json");
    let interval_ticks = match metrics_path {
        Some(_) => {
            let ms = args.get_f64("metrics-interval", 100.0)?;
            anyhow::ensure!(ms > 0.0, "--metrics-interval must be > 0 milliseconds");
            Some((((ms / 1e3) * TICKS_PER_SEC as f64) as u64).max(1))
        }
        None => None,
    };
    Ok((slo_ticks, metrics_path, interval_ticks))
}

/// Residency options from the CLI/environment: `--resident-windows` wins
/// over the `CBQ_RESIDENT_MB` default [`EngineOptions::from_env`] reads;
/// `--no-packed` (or `CBQ_PACKED=0`) turns packed-domain window pinning
/// off, `--packed` merely states the default explicitly.
fn engine_options(args: &Args) -> Result<EngineOptions> {
    let mut opts = EngineOptions::from_env();
    if let Some(n) = args.get("resident-windows") {
        let n: usize = n
            .parse()
            .map_err(|_| anyhow!("--resident-windows expects an integer, got `{n}`"))?;
        anyhow::ensure!(n >= 1, "--resident-windows must be >= 1");
        opts.resident_windows = Some(n);
    }
    if args.flag("no-packed") {
        opts.packed = false;
    } else if args.flag("packed") {
        opts.packed = true;
    }
    Ok(opts)
}

/// Shared by the burst and live serve-bench paths: resolve `--snapshot`,
/// load it under `name` (mmap-lazily when `mode` says so), verify the
/// fingerprint against the artifacts and bind an engine with the CLI's
/// residency budget. Keeping this in one place means the paths cannot
/// drift.
fn load_serve_engine<'rt>(
    args: &Args,
    art: &'rt Artifacts,
    rt: &'rt dyn Backend,
    name: &str,
    mode: LoadMode,
) -> Result<(String, ServeEngine<'rt>)> {
    let path = args
        .get("snapshot")
        .ok_or_else(|| anyhow!("serve-bench requires --snapshot PATH"))?;
    let mut reg = ModelRegistry::new();
    let snap = reg.load_with(name, path, mode)?;
    let mism = snapshot::fingerprint_mismatches(&snap.meta.cfg, art.cfg(&snap.meta.cfg.name)?);
    if !mism.is_empty() {
        bail!("snapshot/artifacts mismatch:\n  {}", mism.join("\n  "));
    }
    if mode == LoadMode::Mmap {
        if let Some(lazy) = snap.model.lazy() {
            if !lazy.is_mapped() {
                println!(
                    "note: mmap-lazy loading selected but the file is not \
                     memory-mapped ({}); windows still load lazily",
                    if lazy.container().version == 1 {
                        "v1 snapshot — re-export for true mapped loading"
                    } else {
                        "mapping unavailable on this platform/configuration"
                    }
                );
            }
        }
    }
    let engine = ServeEngine::with_options(rt, art, snap, engine_options(args)?)?;
    Ok((path.to_string(), engine))
}

/// Pretty one-liner for an engine's residency accounting.
fn residency_line(engine: &ServeEngine) -> String {
    let r = engine.residency();
    format!(
        "{}/{} windows resident ({}), {} pinned (peak {}), {} faults / {} hits / \
         {} evictions, {} prefetches ({} hit)",
        r.resident_windows,
        engine.plan_len(),
        if engine.is_packed() { "packed" } else { "f32" },
        fmt_bytes(r.resident_bytes),
        fmt_bytes(r.peak_bytes),
        r.faults,
        r.hits,
        r.evictions,
        r.prefetches,
        r.prefetch_hits,
    )
}

fn residency_json(engine: &ServeEngine) -> Value {
    let r = engine.residency();
    Value::obj(vec![
        ("lazy", Value::Bool(engine.is_lazy())),
        ("packed", Value::Bool(engine.is_packed())),
        ("plan_windows", Value::num(engine.plan_len() as f64)),
        ("resident_windows", Value::num(r.resident_windows as f64)),
        ("resident_bytes", Value::num(r.resident_bytes as f64)),
        ("peak_windows", Value::num(r.peak_windows as f64)),
        ("peak_bytes", Value::num(r.peak_bytes as f64)),
        ("faults", Value::num(r.faults as f64)),
        ("hits", Value::num(r.hits as f64)),
        ("evictions", Value::num(r.evictions as f64)),
        ("prefetches", Value::num(r.prefetches as f64)),
        ("prefetch_hits", Value::num(r.prefetch_hits as f64)),
    ])
}

/// `cbq serve-bench --live`: replay a seeded synthetic arrival trace
/// through the priority scheduler over a snapshot-bound engine.
fn cmd_serve_live(args: &Args, art: &Artifacts, rt: &dyn Backend) -> Result<()> {
    use cbq::serve::clock::{Clock, RealClock, SimClock, TICKS_PER_SEC};
    use cbq::serve::scheduler::{synth_trace, Scheduler, SchedulerCfg, TraceSpec};

    let mode = if args.flag("mmap") { LoadMode::Mmap } else { LoadMode::Eager };
    let (path, engine) = load_serve_engine(args, art, rt, "live", mode)?;
    let cfg = engine.snapshot().meta.cfg.clone();
    let label = engine.snapshot().meta.label.clone();

    let rate = args.get_f32("arrival-rate", 256.0)?;
    anyhow::ensure!(rate > 0.0, "--arrival-rate must be > 0 requests/s");
    let seed = args.get_u64("trace-seed", 7)?;
    let n_requests = args.get_usize("trace-requests", 64)?;
    anyhow::ensure!(n_requests > 0, "--trace-requests must be > 0");
    let dispatch = args.get_usize("dispatch", 1)?.max(1);
    let queue_cap = args.get_usize("queue-cap", 0)?;
    let priorities = args.flag("priorities");
    let real = args.flag("real-clock");
    let (slo_ticks, metrics_path, interval_ticks) = metrics_args(args)?;

    let mean_gap = (TICKS_PER_SEC as f64 / rate as f64).max(1.0) as u64;
    let spec = TraceSpec {
        seed,
        requests: n_requests,
        mean_gap_ticks: mean_gap,
        seq: cfg.seq,
        vocab: cfg.vocab as u32,
        priorities,
    };
    let trace = synth_trace(&spec);

    println!(
        "live serve: {} requests @ ~{rate:.0}/s (seed {seed}), {} clock, dispatch {dispatch}, \
         queue cap {}, priorities {}",
        trace.len(),
        if real { "real" } else { "simulated" },
        if queue_cap == 0 { "unlimited".to_string() } else { queue_cap.to_string() },
        if priorities { "on" } else { "off (all batch)" },
    );
    if let Some(t) = slo_ticks {
        println!(
            "SLO controller armed: interactive e2e p99 target {:.2}ms ({t} ticks) — \
             Background sheds on violation, recovers with hysteresis",
            t as f64 / TICKS_PER_SEC as f64 * 1e3,
        );
    }

    // warm-up dispatch so the first cycle pays no first-call costs
    engine.execute(&trace[0].request.rows[..1])?;

    let scfg = SchedulerCfg {
        queue_cap: if queue_cap == 0 { None } else { Some(queue_cap) },
        dispatch,
        slo_p99_ticks: slo_ticks,
        metrics_interval_ticks: interval_ticks,
        ..Default::default()
    };
    let metrics = ServeMetrics::with_sink(Box::new(StderrAlerts));
    let sim = SimClock::new();
    let realc = RealClock::new();
    let clock: &dyn Clock = if real { &realc } else { &sim };
    if engine.is_lazy() {
        metrics.sample_residency(engine.residency(), clock.now());
    }
    let out =
        Scheduler::new(clock, scfg.clone()).run_with_metrics(&engine, &trace, Some(&metrics))?;
    if engine.is_lazy() {
        metrics.sample_residency(engine.residency(), clock.now());
    }

    // optional determinism verification: replay the trace under the
    // simulated clock at two lane counts, each with a fresh metrics
    // instance (so the measured run's residency samples cannot leak in);
    // responses, decisions AND the alert/snapshot stream must come out
    // identical
    let verified = if args.flag("verify-determinism") {
        let other = if dispatch == 1 { 4 } else { 1 };
        let c1 = SimClock::new();
        let m1 = ServeMetrics::new();
        let baseline =
            Scheduler::new(&c1, scfg.clone()).run_with_metrics(&engine, &trace, Some(&m1))?;
        let c2 = SimClock::new();
        let m2 = ServeMetrics::new();
        let b = Scheduler::new(&c2, SchedulerCfg { dispatch: other, ..scfg.clone() })
            .run_with_metrics(&engine, &trace, Some(&m2))?;
        if baseline.responses != b.responses
            || baseline.decisions != b.decisions
            || baseline.cycles != b.cycles
            || m1.alerts() != m2.alerts()
            || m1.snapshot(0) != m2.snapshot(0)
        {
            bail!(
                "deterministic replay FAILED: dispatch {dispatch} vs {other} diverged under \
                 the simulated clock"
            );
        }
        println!(
            "deterministic replay verified: dispatch {dispatch} vs {other} identical \
             (responses + decisions + alerts + metrics)"
        );
        Some(true)
    } else {
        None
    };

    let s = &out.stats;
    let mut t = Table::new(
        format!(
            "live serve-bench ({} cycles, {} window dispatches/forward)",
            out.cycles,
            engine.plan_len()
        ),
        &[
            "requests", "admitted", "shed", "rejected", "dispatches", "occupancy", "tok/s",
            "req/s", "wall",
        ],
    );
    t.row(&[
        s.requests.to_string(),
        (s.requests - s.rejected - s.shed).to_string(),
        s.shed.to_string(),
        s.rejected.to_string(),
        s.dispatches.to_string(),
        format!("{:.1}%", s.occupancy() * 100.0),
        fmt_f(s.tokens_per_s(), 0),
        fmt_f(s.requests_per_s(), 1),
        format!("{:.3}s", s.wall_seconds),
    ]);
    t.print();

    let mut t = Table::new(
        "per-class latency (queue wait / service, ms)",
        &["class", "submitted", "done", "rejected", "q p50", "q p95", "q p99", "s p50", "s p95", "s p99"],
    );
    for c in &s.class_lat {
        t.row(&[
            c.class.clone(),
            c.submitted.to_string(),
            c.completed.to_string(),
            c.rejected.to_string(),
            fmt_f(c.queue_p50_s * 1e3, 2),
            fmt_f(c.queue_p95_s * 1e3, 2),
            fmt_f(c.queue_p99_s * 1e3, 2),
            fmt_f(c.service_p50_s * 1e3, 2),
            fmt_f(c.service_p95_s * 1e3, 2),
            fmt_f(c.service_p99_s * 1e3, 2),
        ]);
    }
    t.print();
    if engine.is_lazy() {
        println!("mmap residency: {}", residency_line(&engine));
    }
    if !real {
        println!(
            "(simulated clock: latencies are modeled at {} ticks/dispatch and \
             replay-deterministic; pass --real-clock for wall-time latencies)",
            scfg.service_ticks_per_dispatch
        );
    }

    write_json(
        args,
        &Value::obj(vec![
            ("command", Value::str("serve-bench")),
            ("mode", Value::str("live")),
            ("snapshot", Value::str(path)),
            ("label", Value::str(label)),
            ("backend", Value::str(rt.name())),
            (
                "live",
                Value::obj(vec![
                    ("trace_seed", Value::num(seed as f64)),
                    ("arrival_rate", Value::num(rate as f64)),
                    ("requests", Value::num(trace.len() as f64)),
                    ("priorities", Value::Bool(priorities)),
                    ("clock", Value::str(if real { "real" } else { "sim" })),
                    ("queue_cap", Value::num(queue_cap as f64)),
                    ("dispatch", Value::num(dispatch as f64)),
                    ("cycles", Value::num(out.cycles as f64)),
                    ("admitted", Value::num((s.requests - s.rejected - s.shed) as f64)),
                    ("shed", Value::num(s.shed as f64)),
                    ("rejected", Value::num(s.rejected as f64)),
                    (
                        "slo_p99_ticks",
                        slo_ticks.map(|t| Value::num(t as f64)).unwrap_or(Value::Null),
                    ),
                    ("alerts", Value::num(metrics.alerts().len() as f64)),
                    ("tokens_per_s", Value::num(s.tokens_per_s())),
                    ("requests_per_s", Value::num(s.requests_per_s())),
                    ("occupancy", Value::num(s.occupancy())),
                    ("wall_seconds", Value::num(s.wall_seconds)),
                    (
                        "deterministic_replay",
                        match verified {
                            Some(v) => Value::Bool(v),
                            None => Value::Null,
                        },
                    ),
                    ("classes", Value::arr(s.class_lat.iter().map(class_lat_json).collect())),
                ]),
            ),
            ("stats", serve_stats_json(s)),
            ("residency", residency_json(&engine)),
        ]),
    )?;
    write_metrics_json(metrics_path, &metrics, slo_ticks, clock.now())?;
    Ok(())
}

/// `cbq serve-bench --generate`: token generation over the KV-cached
/// decode path with continuous batching — seeded arrival trace, per-token
/// latency percentiles, decode tokens/s, and an always-on equivalence gate
/// against the one-request-at-a-time reference.
fn cmd_serve_generate(args: &Args, art: &Artifacts, rt: &dyn Backend) -> Result<()> {
    use cbq::serve::clock::{ticks_to_secs, Clock, RealClock, SimClock, TICKS_PER_SEC};
    use cbq::serve::{synth_gen_trace, GenCfg, GenTraceSpec, GenerateEngine};

    // packed decode computes straight from the snapshot's codes, which
    // only lazy (mmap) loading retains — so packed generation implies
    // mmap-lazy windows, and that combination is the native-backend
    // default (`--no-packed` / `CBQ_PACKED=0` fall back to eager f32)
    let packed_default = rt.name() == "native"
        && cbq::runtime::backend::kernels::packed_enabled()
        && !args.flag("no-packed");
    let mode =
        if args.flag("mmap") || packed_default { LoadMode::Mmap } else { LoadMode::Eager };
    let (path, engine) = load_serve_engine(args, art, rt, "generate", mode)?;
    let cfg = engine.snapshot().meta.cfg.clone();
    let label = engine.snapshot().meta.label.clone();
    let gen = GenerateEngine::new(&engine)?;

    let max_new = args.get_usize("max-new-tokens", 8)?;
    anyhow::ensure!(max_new >= 1, "--max-new-tokens must be >= 1");
    let n_requests = args.get_usize("gen-requests", 16)?;
    anyhow::ensure!(n_requests > 0, "--gen-requests must be > 0");
    let rate = args.get_f32("arrival-rate", 256.0)?;
    anyhow::ensure!(rate > 0.0, "--arrival-rate must be > 0 requests/s");
    let seed = args.get_u64("trace-seed", 7)?;
    let dispatch = args.get_usize("dispatch", 1)?.max(1);
    let queue_cap = args.get_usize("queue-cap", 0)?;
    let slots = args.get_usize("slots", 4)?;
    anyhow::ensure!(slots >= 1, "--slots must be >= 1");
    let real = args.flag("real-clock");
    // generate records metrics after the decode loop, so the SLO
    // controller and periodic snapshots (scheduler-loop features) do not
    // apply here — only the always-on counters/histograms and the dump
    let (_, metrics_path, _) = metrics_args(args)?;

    let spec = GenTraceSpec {
        requests: n_requests,
        mean_gap: (TICKS_PER_SEC as f64 / rate as f64).max(1.0) as u64,
        seed,
        vocab: cfg.vocab,
        max_prompt: (cfg.seq / 2).max(1),
        max_new_tokens: max_new,
    };
    let trace = synth_gen_trace(&spec);
    let gcfg = GenCfg {
        max_new_tokens: max_new,
        slots,
        queue_cap: if queue_cap == 0 { None } else { Some(queue_cap) },
        dispatch,
        ..Default::default()
    };

    println!(
        "generate: {} requests @ ~{rate:.0}/s (seed {seed}), up to {max_new} new tokens, \
         {slots} slots, dispatch {dispatch}, {} clock{}",
        trace.len(),
        if real { "real" } else { "simulated" },
        if mode == LoadMode::Mmap { ", mmap-lazy windows" } else { "" },
    );
    println!(
        "decode path: {} weights, {} kernels (CBQ_SIMD to force a tier; all \
         tiers bitwise-equal)",
        if engine.is_packed() { "packed 2/4/8-bit" } else { "f32" },
        cbq::runtime::backend::kernels::simd_tier().name(),
    );

    // warm-up: fault in every window once so the timed run measures
    // steady-state decode, not first-touch materialization
    gen.decode_reference(&trace[0].request.prompt, 1)?;

    let metrics = ServeMetrics::with_sink(Box::new(StderrAlerts));
    let sim = SimClock::new();
    let realc = RealClock::new();
    let clock: &dyn Clock = if real { &realc } else { &sim };
    if engine.is_lazy() {
        metrics.sample_residency(engine.residency(), clock.now());
    }
    let (outcomes, stats) = gen.run_with_metrics(&trace, &gcfg, clock, Some(&metrics))?;
    if engine.is_lazy() {
        metrics.sample_residency(engine.residency(), clock.now());
    }

    // equivalence gate: every completed request's token stream must equal
    // the one-request-at-a-time greedy reference over the same prompt
    let mut streams_match = true;
    for o in outcomes.iter().filter(|o| !o.rejected) {
        let a = &trace[o.seq];
        let want = gen.decode_reference(
            &a.request.prompt,
            a.request.max_new_tokens.min(gcfg.max_new_tokens),
        )?;
        if o.tokens != want {
            streams_match = false;
            eprintln!(
                "request {}: continuous batch decoded {:?}, sequential reference {:?}",
                o.seq, o.tokens, want
            );
        }
    }

    // optional determinism verification: replay under the simulated clock
    // at two lane counts, each with a fresh metrics instance; token
    // streams, ticks, the per-step admission log AND the recorded
    // counters/histograms must come out identical
    let verified = if args.flag("verify-determinism") {
        let other = if dispatch == 1 { 4 } else { 1 };
        let c1 = SimClock::new();
        let m1 = ServeMetrics::new();
        let (base_out, base_stats) = gen.run_with_metrics(&trace, &gcfg, &c1, Some(&m1))?;
        let c2 = SimClock::new();
        let m2 = ServeMetrics::new();
        let (out2, stats2) = gen.run_with_metrics(
            &trace,
            &GenCfg { dispatch: other, ..gcfg.clone() },
            &c2,
            Some(&m2),
        )?;
        if base_out != out2 || base_stats.steps != stats2.steps || m1.snapshot(0) != m2.snapshot(0)
        {
            bail!(
                "deterministic replay FAILED: dispatch {dispatch} vs {other} diverged under \
                 the simulated clock"
            );
        }
        println!(
            "deterministic replay verified: dispatch {dispatch} vs {other} identical \
             (token streams + emission ticks + admission log + metrics)"
        );
        Some(true)
    } else {
        None
    };

    anyhow::ensure!(
        stats.steps.iter().all(|s| s.offered == s.admitted + s.rejected),
        "admission conservation violated (offered != admitted + rejected)"
    );

    let mut t = Table::new(
        format!(
            "generate serve-bench ({} decode steps, {} window dispatches/step)",
            stats.decode_steps,
            engine.plan_len()
        ),
        &[
            "requests", "completed", "rejected", "tokens", "tok/s", "peak batch", "tok p50",
            "tok p95", "tok p99", "wall",
        ],
    );
    t.row(&[
        stats.requests.to_string(),
        stats.completed.to_string(),
        stats.rejected.to_string(),
        stats.tokens.to_string(),
        fmt_f(stats.tokens_per_s, 0),
        format!("{}/{slots}", stats.peak_active),
        format!("{:.2}ms", ticks_to_secs(stats.tok_p50) * 1e3),
        format!("{:.2}ms", ticks_to_secs(stats.tok_p95) * 1e3),
        format!("{:.2}ms", ticks_to_secs(stats.tok_p99) * 1e3),
        format!("{:.3}s", ticks_to_secs(stats.wall_ticks)),
    ]);
    t.print();
    println!(
        "token streams identical to sequential reference: {}",
        if streams_match { "yes" } else { "NO — decode bug" }
    );
    if engine.is_lazy() {
        println!("mmap residency: {}", residency_line(&engine));
    }
    if !real {
        println!(
            "(simulated clock: per-token latencies are modeled at {} ticks/step and \
             replay-deterministic; pass --real-clock for wall-time latencies)",
            gcfg.service_ticks_per_step
        );
    }
    anyhow::ensure!(streams_match, "continuous batching diverged from the sequential reference");

    write_json(
        args,
        &Value::obj(vec![
            ("command", Value::str("serve-bench")),
            ("mode", Value::str("generate")),
            ("snapshot", Value::str(path)),
            ("label", Value::str(label)),
            ("backend", Value::str(rt.name())),
            ("packed", Value::Bool(engine.is_packed())),
            ("simd", Value::str(cbq::runtime::backend::kernels::simd_tier().name())),
            ("generate", generate_stats_json(&stats, seed, max_new, real, verified)),
            ("residency", residency_json(&engine)),
        ]),
    )?;
    write_metrics_json(metrics_path, &metrics, None, clock.now())?;
    Ok(())
}

/// The `generate` JSON object shared by the CLI and the bench harness.
fn generate_stats_json(
    stats: &cbq::serve::GenStats,
    seed: u64,
    max_new: usize,
    real_clock: bool,
    verified: Option<bool>,
) -> Value {
    use cbq::serve::clock::ticks_to_secs;
    Value::obj(vec![
        ("trace_seed", Value::num(seed as f64)),
        ("max_new_tokens", Value::num(max_new as f64)),
        ("clock", Value::str(if real_clock { "real" } else { "sim" })),
        ("requests", Value::num(stats.requests as f64)),
        ("completed", Value::num(stats.completed as f64)),
        ("rejected", Value::num(stats.rejected as f64)),
        ("decode_steps", Value::num(stats.decode_steps as f64)),
        ("tokens", Value::num(stats.tokens as f64)),
        ("decode_tokens_per_s", Value::num(stats.tokens_per_s)),
        ("tok_p50_s", Value::num(ticks_to_secs(stats.tok_p50))),
        ("tok_p95_s", Value::num(ticks_to_secs(stats.tok_p95))),
        ("tok_p99_s", Value::num(ticks_to_secs(stats.tok_p99))),
        ("wall_seconds", Value::num(ticks_to_secs(stats.wall_ticks))),
        ("dispatch", Value::num(stats.dispatch_lanes as f64)),
        ("peak_active", Value::num(stats.peak_active as f64)),
        (
            "deterministic_replay",
            match verified {
                Some(v) => Value::Bool(v),
                None => Value::Null,
            },
        ),
    ])
}

/// `--model` with a sensible default: the artifacts' sole config when
/// there is exactly one (the `cbq synth` case).
fn model_arg<'a>(args: &'a Args, art: &'a Artifacts) -> &'a str {
    args.get("model").unwrap_or_else(|| art.default_model())
}

fn cmd_synth(args: &Args) -> Result<()> {
    let out = args.get("out").unwrap_or("artifacts");
    let mut spec = synth::SynthSpec::tiny();
    spec.pretrain_steps = args.get_usize("steps", spec.pretrain_steps)?;
    spec.seed = args.get_usize("seed", spec.seed as usize)? as u64;
    let t0 = std::time::Instant::now();
    let report = synth::generate(out, &spec)?;
    println!(
        "synthetic artifacts at {out}: model `{}` (d={} L={} heads={} ffn={} vocab={} seq={}),",
        report.cfg.name,
        report.cfg.d_model,
        report.cfg.n_layers,
        report.cfg.n_heads,
        report.cfg.d_ffn,
        report.cfg.vocab,
        report.cfg.seq,
    );
    println!(
        "  {} executables, {} quantizable weights, pretrain loss {:.3} ({:.1}s)",
        report.n_executables,
        report.weight_params,
        report.pretrain_loss,
        t0.elapsed().as_secs_f64()
    );
    println!("next: cbq --artifacts {out} quantize --backend native");
    Ok(())
}

fn cmd_snapshot_info(args: &Args) -> Result<()> {
    let path = args
        .get("snapshot")
        .ok_or_else(|| anyhow!("snapshot-info requires --snapshot PATH"))?;
    let info = snapshot::inspect(path)?;
    println!(
        "{path}: CBQS v{} — model `{}` {} ({}-rounding), {} tensors, {}",
        info.version,
        info.meta.cfg.name,
        info.meta.label,
        info.meta.rounding.name(),
        info.tensors.len(),
        fmt_bytes(info.file_bytes),
    );
    println!("checksum: OK (CRC-32 verified over header + payload)");
    let c = &info.meta.cfg;
    println!(
        "config fingerprint: d_model={} n_layers={} n_heads={} d_ffn={} vocab={} seq={} batch={}",
        c.d_model, c.n_layers, c.n_heads, c.d_ffn, c.vocab, c.seq, c.batch
    );
    // fingerprint check is best-effort: snapshot-info works without artifacts
    match args
        .get("artifacts")
        .map(Artifacts::load)
        .unwrap_or_else(Artifacts::discover)
    {
        Ok(art) => match art.cfg(&c.name) {
            Ok(acfg) => {
                let mism = snapshot::fingerprint_mismatches(c, acfg);
                if mism.is_empty() {
                    println!("fingerprint vs artifacts `{}`: OK", c.name);
                } else {
                    println!("fingerprint vs artifacts `{}`: MISMATCH", c.name);
                    for m in &mism {
                        println!("  {m}");
                    }
                }
            }
            Err(_) => println!("fingerprint: artifacts have no config `{}`", c.name),
        },
        Err(_) => println!("fingerprint: no artifacts directory to compare against"),
    }

    let mut t = Table::new("packed weight codes", &["bits", "tensors", "packed bytes"]);
    for (bits, n, bytes) in info.packed_by_bits() {
        t.row(&[format!("w{bits}"), n.to_string(), fmt_bytes(bytes)]);
    }
    t.print();
    println!(
        "payload: {} packed codes + {} f32 (scales/LoRA/clips/embeddings)",
        fmt_bytes(info.packed_code_bytes),
        fmt_bytes(info.f32_bytes)
    );

    // resident-vs-mapped accounting: what the file costs to *serve*, not
    // just to store — this is what sizes CBQ_RESIDENT_MB
    let mut t = Table::new("resident-vs-mapped accounting", &["figure", "bytes", "meaning"]);
    t.row(&["on disk".into(), fmt_bytes(info.file_bytes), "the CBQS file".into()]);
    t.row(&["unpacked".into(), fmt_bytes(info.unpacked_bytes), "all tensors as f32".into()]);
    t.row(&[
        "eager resident".into(),
        fmt_bytes(info.resident_estimate_bytes),
        "full load (incl. per-linear v0)".into(),
    ]);
    t.row(&[
        "per-block max".into(),
        fmt_bytes(info.max_block_resident_bytes),
        "largest block, pinned as f32".into(),
    ]);
    t.row(&[
        "packed resident".into(),
        fmt_bytes(info.packed_resident_estimate_bytes),
        "all blocks under --packed (codes+scales)".into(),
    ]);
    t.row(&[
        "per-block max (packed)".into(),
        fmt_bytes(info.max_block_packed_resident_bytes),
        "largest block under --packed".into(),
    ]);
    t.print();
    println!(
        "sizing: a width-w pinned window keeps ~w x {} resident ({} under \
         --packed); set CBQ_RESIDENT_MB / --resident-windows from that",
        fmt_bytes(info.max_block_resident_bytes),
        fmt_bytes(info.max_block_packed_resident_bytes),
    );
    if info.version >= 2 {
        println!(
            "offset table: {} records, payloads 64-byte aligned (mmap-lazy loadable)",
            info.tensors.len()
        );
    } else {
        println!("offset table: none on disk (v1 frame) — re-export for mmap-lazy loading");
    }

    let mut largest: Vec<_> = info.tensors.iter().collect();
    largest.sort_by(|a, b| b.bytes.cmp(&a.bytes).then(a.name.cmp(&b.name)));
    let mut t = Table::new(
        "largest tensors",
        &["name", "dtype", "dims", "bytes", "unpacked", "offset", "block"],
    );
    for ti in largest.iter().take(8) {
        t.row(&[
            ti.name.clone(),
            if ti.dtype == "packed" { format!("w{}", ti.bits) } else { "f32".into() },
            format!("{:?}", ti.dims),
            fmt_bytes(ti.bytes as u64),
            fmt_bytes(ti.unpacked_bytes),
            format!("0x{:x}", ti.offset),
            if ti.group < 0 { "-".into() } else { ti.group.to_string() },
        ]);
    }
    t.print();

    write_json(
        args,
        &Value::obj(vec![
            ("command", Value::str("snapshot-info")),
            ("snapshot", Value::str(path)),
            ("version", Value::num(info.version as f64)),
            ("model", Value::str(info.meta.cfg.name.clone())),
            ("label", Value::str(info.meta.label.clone())),
            ("rounding", Value::str(info.meta.rounding.name())),
            ("tensors", Value::num(info.tensors.len() as f64)),
            ("file_bytes", Value::num(info.file_bytes as f64)),
            ("packed_code_bytes", Value::num(info.packed_code_bytes as f64)),
            ("f32_bytes", Value::num(info.f32_bytes as f64)),
            ("unpacked_bytes", Value::num(info.unpacked_bytes as f64)),
            ("resident_estimate_bytes", Value::num(info.resident_estimate_bytes as f64)),
            ("max_block_resident_bytes", Value::num(info.max_block_resident_bytes as f64)),
            (
                "packed_resident_estimate_bytes",
                Value::num(info.packed_resident_estimate_bytes as f64),
            ),
            (
                "max_block_packed_resident_bytes",
                Value::num(info.max_block_packed_resident_bytes as f64),
            ),
            ("checksum_ok", Value::Bool(info.checksum_ok)),
            (
                "packed_by_bits",
                Value::arr(
                    info.packed_by_bits()
                        .into_iter()
                        .map(|(bits, n, bytes)| {
                            Value::obj(vec![
                                ("bits", Value::num(bits as f64)),
                                ("tensors", Value::num(n as f64)),
                                ("bytes", Value::num(bytes as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "offset_table",
                Value::arr(
                    info.tensors
                        .iter()
                        .map(|ti| {
                            Value::obj(vec![
                                ("name", Value::str(ti.name.clone())),
                                ("dtype", Value::str(ti.dtype)),
                                ("bits", Value::num(ti.bits as f64)),
                                (
                                    "dims",
                                    Value::arr(
                                        ti.dims.iter().map(|&d| Value::num(d as f64)).collect(),
                                    ),
                                ),
                                ("bytes", Value::num(ti.bytes as f64)),
                                ("unpacked_bytes", Value::num(ti.unpacked_bytes as f64)),
                                ("offset", Value::num(ti.offset as f64)),
                                ("group", Value::num(ti.group as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    )?;
    Ok(())
}

/// `cbq fuzz` — one deterministic adversarial fuzz run. Exit status is
/// nonzero when any finding survives, so CI can gate on it directly; the
/// printed digest lets a second invocation certify bitwise replay.
fn cmd_fuzz(args: &Args) -> Result<()> {
    use cbq::fuzzing::{self, FuzzOpts, TARGETS};
    let target = args.get("target").unwrap_or("snapshot");
    if !TARGETS.contains(&target) {
        bail!("--target must be one of {TARGETS:?}, got `{target}`");
    }
    let seed = args.get_u64("seed", 7)?;
    let iters = args.get_u64("iters", 500)?;
    let mut opts = FuzzOpts::new(seed, iters);
    if let Some(dir) = args.get("fixtures") {
        opts.fixtures = Some(std::path::PathBuf::from(dir));
    }
    let report = fuzzing::run_target(target, &opts)?;
    println!(
        "fuzz target={} seed={} iters={} digest={:016x} ok={} rejected={} findings={}",
        report.target,
        report.seed,
        report.iters,
        report.digest,
        report.cases_ok,
        report.cases_rejected,
        report.findings.len()
    );
    for f in &report.findings {
        eprintln!("FINDING iter {}: {}", f.iter, f.summary);
        if let Some(p) = &f.fixture {
            eprintln!("  minimized fixture: {}", p.display());
        }
    }
    write_json(
        args,
        &Value::obj(vec![
            ("schema", Value::str("cbq-fuzz-v1")),
            ("target", Value::str(report.target.as_str())),
            ("seed", Value::num(report.seed as f64)),
            ("iters", Value::num(report.iters as f64)),
            ("digest", Value::str(format!("{:016x}", report.digest))),
            ("cases_ok", Value::num(report.cases_ok as f64)),
            ("cases_rejected", Value::num(report.cases_rejected as f64)),
            (
                "findings",
                Value::arr(
                    report
                        .findings
                        .iter()
                        .map(|f| {
                            Value::obj(vec![
                                ("iter", Value::num(f.iter as f64)),
                                ("summary", Value::str(f.summary.as_str())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    )?;
    if !report.findings.is_empty() {
        bail!(
            "{} finding(s); replay with `cbq fuzz --target {} --seed {} --iters {}`",
            report.findings.len(),
            report.target,
            report.seed,
            report.iters
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let Some(cmd) = args.command() else {
        print!("{USAGE}");
        return Ok(());
    };

    // commands that need no artifacts directory come first
    match cmd {
        "synth" => return cmd_synth(&args),
        "snapshot-info" => return cmd_snapshot_info(&args),
        "fuzz" => return cmd_fuzz(&args),
        _ => {}
    }

    let art = match args.get("artifacts") {
        Some(p) => Artifacts::load(p)?,
        None => Artifacts::discover()?,
    };
    let rt: Box<dyn Backend> = runtime::create_selected(&art, args.get("backend"))?;
    let rt = rt.as_ref();

    match cmd {
        "info" => {
            println!("artifacts: {:?} (backend: {})", art.dir, rt.name());
            let mut t =
                Table::new("configs", &["name", "d_model", "layers", "heads", "ffn", "windows"]);
            for (name, c) in &art.manifest.configs {
                t.row(&[
                    name.clone(),
                    c.d_model.to_string(),
                    c.n_layers.to_string(),
                    c.n_heads.to_string(),
                    c.d_ffn.to_string(),
                    format!("{:?}", art.manifest.windows.get(name).cloned().unwrap_or_default()),
                ]);
            }
            t.print();
            println!("\n{} executables", art.manifest.executables.len());
        }
        "eval" => {
            let model = model_arg(&args, &art);
            let n = args.get_usize("eval-batches", 16)?;
            let pipe = Pipeline::new(&art, rt, model)?;
            let fp = pipe.fp_model();
            let c4 = pipe.perplexity(&fp, Style::C4, n)?;
            let wiki = pipe.perplexity(&fp, Style::Wiki, n)?;
            println!("FP {model}: ppl(c4) = {c4:.3}, ppl(wiki) = {wiki:.3}");
        }
        "quantize" => {
            let model = model_arg(&args, &art);
            let mut pipe = Pipeline::new(&art, rt, model)?;
            let job = build_job(&args, pipe.cfg.n_layers)?;
            let eval_batches = args.get_usize("eval-batches", 16)?;
            println!("running {} on model {model} ({} backend)...", job.label(), rt.name());
            let (qm, summary) = pipe.run(&job)?;
            let fp = pipe.fp_model();
            let mut t = Table::new(
                format!("{} (quantized in {:.1}s)", job.label(), summary.quant_seconds),
                &["model", "ppl c4", "ppl wiki"],
            );
            let c4 = pipe.perplexity(&qm, Style::C4, eval_batches)?;
            let wiki = pipe.perplexity(&qm, Style::Wiki, eval_batches)?;
            let fc4 = pipe.perplexity(&fp, Style::C4, eval_batches)?;
            let fwiki = pipe.perplexity(&fp, Style::Wiki, eval_batches)?;
            t.row(&["FP".into(), fmt_f(fc4, 3), fmt_f(fwiki, 3)]);
            t.row(&[job.label(), fmt_f(c4, 3), fmt_f(wiki, 3)]);
            t.print();
            if !summary.window_losses.is_empty() {
                println!("window losses: {:?}", summary.window_losses);
            }
            let stats = rt.stats();
            println!(
                "runtime[{}]: {} executions, {:.1}ms exec, {:.1}ms compile",
                rt.name(),
                stats.executions,
                stats.execute_ms,
                stats.compile_ms
            );
        }
        "export" => {
            let model = model_arg(&args, &art);
            let mut pipe = Pipeline::new(&art, rt, model)?;
            let job = build_job(&args, pipe.cfg.n_layers)?;
            println!("running {} on model {model} ({} backend)...", job.label(), rt.name());
            let (qm, summary) = pipe.run(&job)?;

            let eval_batches = args.get_usize("eval-batches", 8)?;
            let ppl = if eval_batches > 0 {
                Some(pipe.perplexity(&qm, Style::C4, eval_batches)?)
            } else {
                None
            };

            let default_out = format!(
                "{model}_{}.cbqs",
                job.bits.label().to_lowercase().replace('*', "s")
            );
            let out = args.get("out").unwrap_or(&default_out).to_string();
            let report = snapshot::save(&out, &pipe.cfg, &qm)?;

            let mut t = Table::new(
                format!("export {} -> {out}", job.label()),
                &["snapshot", "f32 equivalent", "ratio", "packed codes"],
            );
            t.row(&[
                fmt_bytes(report.file_bytes),
                fmt_bytes(report.f32_equiv_bytes),
                format!("{:.1}%", report.compression_ratio() * 100.0),
                fmt_bytes(report.packed_code_bytes),
            ]);
            t.print();
            if let Some(p) = ppl {
                println!("in-memory ppl(c4, {eval_batches} batches) = {p:.6}");
                println!("verify with: cbq load-eval --snapshot={out} --eval-batches={eval_batches}");
            }
            println!("quantized in {:.1}s — serve forever.", summary.quant_seconds);

            write_json(
                &args,
                &Value::obj(vec![
                    ("command", Value::str("export")),
                    ("model", Value::str(model)),
                    ("label", Value::str(job.label())),
                    ("backend", Value::str(rt.name())),
                    ("out", Value::str(out.clone())),
                    ("file_bytes", Value::num(report.file_bytes as f64)),
                    ("f32_equiv_bytes", Value::num(report.f32_equiv_bytes as f64)),
                    ("compression_ratio", Value::num(report.compression_ratio())),
                    ("packed_code_bytes", Value::num(report.packed_code_bytes as f64)),
                    ("quant_seconds", Value::num(summary.quant_seconds)),
                    ("ppl_c4", ppl.map(Value::num).unwrap_or(Value::Null)),
                ]),
            )?;
        }
        "load-eval" => {
            let path = args
                .get("snapshot")
                .ok_or_else(|| anyhow!("load-eval requires --snapshot PATH"))?;
            let snap = snapshot::load(path)?;
            let cfg_name = snap.meta.cfg.name.clone();
            let mism = snapshot::fingerprint_mismatches(&snap.meta.cfg, art.cfg(&cfg_name)?);
            if !mism.is_empty() {
                bail!(
                    "snapshot fingerprint does not match artifacts config `{cfg_name}`:\n  {}",
                    mism.join("\n  ")
                );
            }
            println!(
                "loaded {path}: model {cfg_name}, {} {}-rounding, checksum OK, fingerprint OK",
                snap.meta.label,
                snap.meta.rounding.name()
            );
            let pipe = Pipeline::new(&art, rt, &cfg_name)?;
            let n = args.get_usize("eval-batches", 16)?;
            let c4 = pipe.perplexity(&snap.model, Style::C4, n)?;
            let wiki = pipe.perplexity(&snap.model, Style::Wiki, n)?;
            let mut t = Table::new(
                format!("load-eval {} ({n} batches)", snap.meta.label),
                &["ppl c4", "ppl wiki"],
            );
            t.row(&[fmt_f(c4, 6), fmt_f(wiki, 6)]);
            t.print();
            println!("(bit-exact: these equal the in-memory pipeline's values)");
            write_json(
                &args,
                &Value::obj(vec![
                    ("command", Value::str("load-eval")),
                    ("snapshot", Value::str(path)),
                    ("model", Value::str(cfg_name.clone())),
                    ("label", Value::str(snap.meta.label.clone())),
                    ("backend", Value::str(rt.name())),
                    ("eval_batches", Value::num(n as f64)),
                    ("ppl_c4", Value::num(c4)),
                    ("ppl_wiki", Value::num(wiki)),
                ]),
            )?;
        }
        "serve-bench" => {
            if args.flag("live") {
                return cmd_serve_live(&args, &art, rt);
            }
            if args.flag("generate") {
                return cmd_serve_generate(&args, &art, rt);
            }
            let mmap = args.flag("mmap");
            let mode = if mmap { LoadMode::Mmap } else { LoadMode::Eager };
            let (path, engine) = load_serve_engine(&args, &art, rt, "bench", mode)?;
            let label = engine.snapshot().meta.label.clone();
            let seq = engine.snapshot().meta.cfg.seq;
            let n_ppl = args.get_usize("ppl-requests", 32)?;
            let n_choice = args.get_usize("choice-requests", 8)?;
            let n_hidden = args.get_usize("hidden-requests", 8)?;
            let queue_cap = args.get_usize("queue-cap", 0)?;
            let dispatch = args.get_usize("dispatch", 1)?.max(1);
            let (_, metrics_path, _) = metrics_args(&args)?;
            let requests = batcher::standard_mix(seq, n_ppl, n_choice, n_hidden);
            anyhow::ensure!(!requests.is_empty(), "request mix is empty — raise --ppl-requests");
            println!(
                "serving {} requests ({} ppl / {} choice / {} hidden) from {} on {} backend{}",
                requests.len(),
                n_ppl,
                n_choice,
                n_hidden,
                label,
                rt.name(),
                if mmap { ", mmap-lazy windows" } else { "" },
            );

            // under --mmap the one-by-one reference runs on a separate,
            // eagerly loaded (always-f32) engine, so the "responses
            // identical" check doubles as the mmap-vs-eager — and, when
            // the lazy engine pins packed, the packed-vs-f32 —
            // bitwise-equality gate
            let eager_engine = if mmap {
                Some(load_serve_engine(&args, &art, rt, "bench-eager", LoadMode::Eager)?.1)
            } else {
                None
            };
            let ref_engine: &ServeEngine = eager_engine.as_ref().unwrap_or(&engine);

            // warm-up dispatch so neither timed run pays first-call costs
            // (the reference engine only needs its own warm-up when it is
            // a distinct eager engine, i.e. under --mmap)
            engine.execute(&requests[0].rows[..1])?;
            if let Some(ref e) = eager_engine {
                e.execute(&requests[0].rows[..1])?;
            }

            // the always-on metrics layer rides along on the batched
            // (production-shaped) run only — the one-by-one reference is a
            // comparison baseline, and double-recording would skew counters
            let metrics = std::sync::Arc::new(ServeMetrics::new());
            let (resp_b, stats_b) = Batcher::coalescing(&engine)
                .with_queue_cap(queue_cap)
                .with_dispatch(dispatch)
                .with_metrics(metrics.clone())
                .run(&engine, &requests)?;
            let (resp_s, stats_s) = Batcher::sequential()
                .with_queue_cap(queue_cap)
                .run(ref_engine, &requests)?;

            // both schedules must produce identical answers (full structural
            // compare: ppl sums, choice picks + scores, hidden token counts);
            // with --mmap this also proves lazy == eager bitwise
            let agree = resp_b == resp_s;

            let mut t = Table::new(
                format!(
                    "serve-bench ({} window dispatches/forward, --dispatch {dispatch})",
                    engine.plan_len()
                ),
                &[
                    "mode", "dispatches", "occupancy", "tok/s", "req/s", "rejected",
                    "in-flight", "lane-occ", "wall",
                ],
            );
            serve_stats_row(&mut t, if mmap { "batched (mmap)" } else { "batched" }, &stats_b);
            serve_stats_row(&mut t, "one-by-one", &stats_s);
            t.print();
            let speedup = stats_b.tokens_per_s() / stats_s.tokens_per_s().max(1e-12);
            println!(
                "batched speedup: {speedup:.2}x tokens/s; responses identical: {}",
                if agree {
                    if mmap { "yes (mmap == eager, bitwise)" } else { "yes" }
                } else {
                    "NO — serving bug"
                }
            );
            if mmap {
                println!("mmap residency: {}", residency_line(&engine));
                if let Some(ref e) = eager_engine {
                    println!(
                        "eager reference keeps {} resident; mmap peak was {}",
                        fmt_bytes(e.residency().resident_bytes),
                        fmt_bytes(engine.residency().peak_bytes),
                    );
                }
            }

            write_json(
                &args,
                &Value::obj(vec![
                    ("command", Value::str("serve-bench")),
                    ("snapshot", Value::str(path)),
                    ("label", Value::str(label)),
                    ("backend", Value::str(rt.name())),
                    ("requests", Value::num(requests.len() as f64)),
                    ("queue_cap", Value::num(queue_cap as f64)),
                    ("dispatch", Value::num(dispatch as f64)),
                    ("mmap", Value::Bool(mmap)),
                    ("packed", Value::Bool(engine.is_packed())),
                    ("batched", serve_stats_json(&stats_b)),
                    ("sequential", serve_stats_json(&stats_s)),
                    ("speedup_tokens_per_s", Value::num(speedup)),
                    ("responses_identical", Value::Bool(agree)),
                    ("residency", residency_json(&engine)),
                    (
                        "eager_resident_bytes",
                        match &eager_engine {
                            Some(e) => Value::num(e.residency().resident_bytes as f64),
                            None => Value::Null,
                        },
                    ),
                ]),
            )?;
            // burst runs have no tick clock; stamp the dump from measured
            // wall time so at_ticks stays monotone with the live modes
            let at_ticks =
                (stats_b.wall_seconds * cbq::serve::TICKS_PER_SEC as f64) as u64;
            if engine.is_lazy() {
                metrics.sample_residency(engine.residency(), at_ticks);
            }
            write_metrics_json(metrics_path, &metrics, None, at_ticks)?;
        }
        "zeroshot" => {
            let model = model_arg(&args, &art);
            let mut pipe = Pipeline::new(&art, rt, model)?;
            let bits =
                BitSpec::new(args.get_usize("w", 4)? as u8, args.get_usize("a", 16)? as u8);
            let mut job = parse_method(&args, bits)?;
            job.calib_sequences = args.get_usize("calib", 32)?;
            let items = args.get_usize("items", 32)?;
            let (qm, _) = pipe.run(&job)?;
            let fp = pipe.fp_model();
            let rq = pipe.zero_shot(&qm, items)?;
            let rf = pipe.zero_shot(&fp, items)?;
            let mut t = Table::new("zero-shot accuracy", &["task", "FP", &job.label()]);
            for (k, v) in &rf.accuracy {
                t.row(&[k.clone(), fmt_f(*v * 100.0, 2), fmt_f(rq.accuracy[k] * 100.0, 2)]);
            }
            t.row(&[
                "Mutual MRR/R@1/R@2".into(),
                format!(
                    "{}/{}/{}",
                    fmt_f(rf.mrr * 100.0, 1),
                    fmt_f(rf.recall1 * 100.0, 1),
                    fmt_f(rf.recall2 * 100.0, 1)
                ),
                format!(
                    "{}/{}/{}",
                    fmt_f(rq.mrr * 100.0, 1),
                    fmt_f(rq.recall1 * 100.0, 1),
                    fmt_f(rq.recall2 * 100.0, 1)
                ),
            ]);
            t.print();
        }
        "hessian" => {
            let model = args.get("model").unwrap_or_else(|| art.model_or_default("t"));
            let pipe = Pipeline::new(&art, rt, model)?;
            for b in args.get("bits").unwrap_or("8,4,2").split(',') {
                let wb: u8 = b.trim().parse()?;
                let probe = HessianProbe::new(&pipe, BitSpec::new(wb, 16))?;
                let h = probe.inter_block_hessian(0.05)?;
                println!("{}", heatmap(&format!("inter-block scale Hessian, W{wb}"), &h));
                println!("off-diagonal mass ratio @ W{wb}: {:.4}", offdiag_ratio(&h));
            }
        }
        other => {
            print!("{USAGE}");
            bail!("unknown command `{other}`");
        }
    }
    Ok(())
}
