//! Finite-difference dependency analysis — reproduces the paper's Figure 1:
//! (a) intra-layer weight Hessian block of one linear,
//! (b) inter-layer Hessian of the loss wrt per-block scale multipliers,
//! (c) the loss surface over joint scale perturbations of two adjacent
//!     blocks.
//!
//! The probe function is the quantized-model reconstruction loss (MSE of
//! final hidden states vs the FP model) on a fixed calibration batch, with
//! per-block scale multipliers applied to every `s_w` in the block — the
//! same quantity the paper visualizes. Off-diagonal growth as bits shrink
//! is the paper's motivating observation (Sec. 2).

use anyhow::Result;

use crate::calib;
use crate::config::{BitSpec, RoundingMode};
use crate::coordinator::Pipeline;
use crate::quant::LINEARS;
use crate::tensor::Tensor;

/// Finite-difference probe of the inter-block loss Hessian (Fig. 1).
pub struct HessianProbe<'p, 'a> {
    pipe: &'p Pipeline<'a>,
    h0: Tensor,
    target: Tensor,
    bits: BitSpec,
}

impl<'p, 'a> HessianProbe<'p, 'a> {
    /// Set up a probe of `pipe`'s model at the given bit spec.
    pub fn new(pipe: &'p Pipeline<'a>, bits: BitSpec) -> Result<Self> {
        let batch = &calib::calibration(pipe.cfg.batch, pipe.cfg.batch, pipe.cfg.seq)[0];
        let x = batch.inputs();
        let h0 = pipe.fp.embed_tokens(&x.data, batch.batch, batch.seq);
        // FP target: final hidden
        let mut target = h0.clone();
        let qs = pipe.init_qstate(&pipe.fp, &BitSpec::new(8, 16), 5, RoundingMode::Nearest);
        let fwd = format!("win_fwd_w1_{}", pipe.cfg_name);
        for k in 0..pipe.cfg.n_layers {
            let zeros = Tensor::zeros(&target.dims);
            let (h, _) = pipe.window_forward(
                &fwd,
                &pipe.fp.blocks[k..k + 1],
                &qs[k..k + 1],
                &target,
                &zeros,
                32767.0,
                0.0,
                0.0,
            )?;
            target = h;
        }
        Ok(Self { pipe, h0, target, bits })
    }

    /// Loss with per-block scale multipliers: block k's s_w scaled by
    /// `mults[k]` (1.0 = learned/init scales).
    pub fn loss_with_scale_mults(&self, mults: &[f32]) -> Result<f32> {
        let pipe = self.pipe;
        let mut qs = pipe.init_qstate(&pipe.fp, &self.bits, 5, RoundingMode::Nearest);
        for (k, m) in mults.iter().enumerate() {
            if (m - 1.0).abs() > 1e-12 {
                for l in LINEARS {
                    let lq = qs[k].get_mut(l).unwrap();
                    for s in lq.s_w.data.iter_mut() {
                        *s *= m;
                    }
                }
            }
        }
        let fwd = format!("win_fwd_w1_{}", pipe.cfg_name);
        let mut h = self.h0.clone();
        for k in 0..pipe.cfg.n_layers {
            let zeros = Tensor::zeros(&h.dims);
            let (h_out, _) = pipe.window_forward(
                &fwd,
                &pipe.fp.blocks[k..k + 1],
                &qs[k..k + 1],
                &h,
                &zeros,
                self.bits.qmax_a(),
                1.0,
                if self.bits.act_enabled() { 1.0 } else { 0.0 },
            )?;
            h = h_out;
        }
        let mut mse = 0.0f64;
        for (a, b) in h.data.iter().zip(&self.target.data) {
            let d = (a - b) as f64;
            mse += d * d;
        }
        Ok((mse / h.data.len() as f64) as f32)
    }

    /// (b): full inter-block scale Hessian via central finite differences.
    pub fn inter_block_hessian(&self, eps: f32) -> Result<Vec<Vec<f32>>> {
        let n = self.pipe.cfg.n_layers;
        let mut h = vec![vec![0.0f32; n]; n];
        let base = vec![1.0f32; n];
        for i in 0..n {
            for j in i..n {
                let v = if i == j {
                    // d2f/dxi2 = (f(+e) - 2 f(0) + f(-e)) / e^2
                    let mut p = base.clone();
                    p[i] = 1.0 + eps;
                    let fp = self.loss_with_scale_mults(&p)?;
                    p[i] = 1.0 - eps;
                    let fm = self.loss_with_scale_mults(&p)?;
                    let f0 = self.loss_with_scale_mults(&base)?;
                    (fp - 2.0 * f0 + fm) / (eps * eps)
                } else {
                    let mut f = [0.0f32; 4];
                    for (idx, (si, sj)) in
                        [(1.0, 1.0), (1.0, -1.0), (-1.0, 1.0), (-1.0, -1.0)].iter().enumerate()
                    {
                        let mut p = base.clone();
                        p[i] = 1.0 + si * eps;
                        p[j] = 1.0 + sj * eps;
                        f[idx] = self.loss_with_scale_mults(&p)?;
                    }
                    (f[0] - f[1] - f[2] + f[3]) / (4.0 * eps * eps)
                };
                h[i][j] = v;
                h[j][i] = v;
            }
        }
        Ok(h)
    }

    /// (c): loss grid over joint scale multipliers of two blocks.
    pub fn pairwise_loss_surface(
        &self,
        block_a: usize,
        block_b: usize,
        grid: &[f32],
    ) -> Result<Vec<Vec<f32>>> {
        let n = self.pipe.cfg.n_layers;
        let mut out = Vec::with_capacity(grid.len());
        for &ma in grid {
            let mut row = Vec::with_capacity(grid.len());
            for &mb in grid {
                let mut p = vec![1.0f32; n];
                p[block_a] = ma;
                p[block_b] = mb;
                row.push(self.loss_with_scale_mults(&p)?);
            }
            out.push(row);
        }
        Ok(out)
    }

    /// (a): intra-layer Hessian over sampled weight entries of one linear.
    /// Probes block-local reconstruction loss (cheaper, same structure).
    pub fn intra_layer_hessian(
        &self,
        block: usize,
        linear: &str,
        n_entries: usize,
        eps: f32,
    ) -> Result<Vec<Vec<f32>>> {
        let pipe = self.pipe;
        let fwd = format!("win_fwd_w1_{}", pipe.cfg_name);
        // block-local FP target
        let qs0 = pipe.init_qstate(&pipe.fp, &BitSpec::new(8, 16), 5, RoundingMode::Nearest);
        let zeros = Tensor::zeros(&self.h0.dims);
        let (target, _) = pipe.window_forward(
            &fwd,
            &pipe.fp.blocks[block..block + 1],
            &qs0[block..block + 1],
            &self.h0,
            &zeros,
            32767.0,
            0.0,
            0.0,
        )?;
        let w = &pipe.fp.blocks[block].linears[linear];
        // strided entry sample across the matrix
        let stride = (w.len() / n_entries).max(1);
        let idxs: Vec<usize> = (0..n_entries).map(|i| (i * stride) % w.len()).collect();

        let loss = |deltas: &[(usize, f32)]| -> Result<f32> {
            let mut blk = pipe.fp.blocks[block].clone();
            {
                let wm = blk.linear_mut(linear);
                for &(ix, d) in deltas {
                    wm.data[ix] += d;
                }
            }
            let mut qsb = pipe.init_qstate(&pipe.fp, &self.bits, 5, RoundingMode::Nearest);
            let (h, _) = pipe.window_forward(
                &fwd,
                std::slice::from_ref(&blk),
                &qsb[block..block + 1],
                &self.h0,
                &Tensor::zeros(&self.h0.dims),
                self.bits.qmax_a(),
                1.0,
                if self.bits.act_enabled() { 1.0 } else { 0.0 },
            )?;
            let _ = &mut qsb;
            let mut mse = 0.0f64;
            for (a, b) in h.data.iter().zip(&target.data) {
                let d = (a - b) as f64;
                mse += d * d;
            }
            Ok((mse / h.data.len() as f64) as f32)
        };

        let n = idxs.len();
        let mut hess = vec![vec![0.0f32; n]; n];
        let f0 = loss(&[])?;
        for a in 0..n {
            for b in a..n {
                let v = if a == b {
                    let fp = loss(&[(idxs[a], eps)])?;
                    let fm = loss(&[(idxs[a], -eps)])?;
                    (fp - 2.0 * f0 + fm) / (eps * eps)
                } else {
                    let fpp = loss(&[(idxs[a], eps), (idxs[b], eps)])?;
                    let fpm = loss(&[(idxs[a], eps), (idxs[b], -eps)])?;
                    let fmp = loss(&[(idxs[a], -eps), (idxs[b], eps)])?;
                    let fmm = loss(&[(idxs[a], -eps), (idxs[b], -eps)])?;
                    (fpp - fpm - fmp + fmm) / (4.0 * eps * eps)
                };
                hess[a][b] = v;
                hess[b][a] = v;
            }
        }
        Ok(hess)
    }
}

/// Off-diagonal mass ratio: sum |H_ij| (i != j) / sum |H_ii| — the summary
/// statistic behind "dependencies intensify at low bits".
pub fn offdiag_ratio(h: &[Vec<f32>]) -> f64 {
    let n = h.len();
    let mut diag = 0.0f64;
    let mut off = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            if i == j {
                diag += h[i][j].abs() as f64;
            } else {
                off += h[i][j].abs() as f64;
            }
        }
    }
    off / diag.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offdiag_ratio_known() {
        let h = vec![vec![2.0, 1.0], vec![1.0, 2.0]];
        assert!((offdiag_ratio(&h) - 0.5).abs() < 1e-9);
        let d = vec![vec![3.0, 0.0], vec![0.0, 3.0]];
        assert_eq!(offdiag_ratio(&d), 0.0);
    }
}
