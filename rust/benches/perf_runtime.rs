//! Runtime hot-path benchmark (`cargo bench --bench perf_runtime`) — the
//! §Perf instrument for the L3 layer.
//!
//! Measures, per model config:
//!   * executable compile time (one-off)
//!   * window-grad step latency (the CBD optimization inner loop)
//!   * full-upload vs pinned-weight execution (weights as persistent device
//!     buffers; only learnable tensors re-uploaded per step)
//!   * quantized-eval throughput (tokens/s through the block chain + head)
//!
//! Results recorded in EXPERIMENTS.md §Perf.

use std::collections::BTreeMap;
use std::time::Instant;

use cbq::calib::{self, corpus::Style};
use cbq::config::{BitSpec, QuantJob, RoundingMode};
use cbq::coordinator::Pipeline;
use cbq::report::{fmt_f, Table};
use cbq::runtime::{self, Artifacts, Backend as _, Bindings, Value};
use cbq::tensor::Tensor;

fn time_n<F: FnMut()>(n: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..n {
        f();
    }
    t0.elapsed().as_secs_f64() / n as f64
}

fn main() {
    let art = Artifacts::discover().expect("run `make artifacts` or `cbq synth` first");
    let model = std::env::var("CBQ_BENCH_MODEL").unwrap_or_else(|_| art.default_model().to_string());
    let reps: usize = std::env::var("CBQ_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(10);
    let rt = runtime::create_selected(&art, None).unwrap();
    let rt = rt.as_ref();
    let pipe = Pipeline::new(&art, rt, &model).unwrap();
    let cfg = pipe.cfg.clone();
    println!("perf_runtime on model `{model}` (d={} L={}), {reps} reps", cfg.d_model, cfg.n_layers);

    // ---- compile costs ----------------------------------------------------
    let mut t = Table::new("compile time (first use)", &["executable", "ms"]);
    for name in [
        format!("win_fwd_w1_{model}"),
        format!("win_grad_w1_{model}"),
        format!("win_grad_w2_{model}"),
        format!("lm_eval_{model}"),
    ] {
        let before = rt.stats().compile_ms;
        rt.warmup(&name).unwrap();
        let after = rt.stats().compile_ms;
        t.row(&[name, fmt_f(after - before, 1)]);
    }
    t.print();

    // ---- window-grad step latency: full upload vs pinned weights ----------
    let job = QuantJob::cbq(BitSpec::w4a4());
    let qstate = pipe.init_qstate(&pipe.fp, &job.bits, job.rank, RoundingMode::Lora);
    let batch = &calib::calibration(cfg.batch, cfg.batch, cfg.seq)[0];
    let h0 = pipe.fp.embed_tokens(&batch.inputs().data, cfg.batch, cfg.seq);

    let build_bindings = |w: usize| -> Bindings {
        let mut b = Bindings::new();
        b.set("h_in", h0.clone());
        b.set("target", Tensor::zeros(&h0.dims));
        for j in 0..w {
            Pipeline::bind_block_weights(&mut b, j, &pipe.fp.blocks[j]);
            Pipeline::bind_qblock(&mut b, j, &qstate[j], 7.0, 1.0, 1.0, false);
        }
        Pipeline::bind_globals(&mut b, 1.0, 10.0, 1e-3, 1.0, 1.0);
        b
    };

    let mut t = Table::new(
        "window-grad step latency (ms)",
        &["window", "full upload", "pinned weights", "speedup"],
    );
    for w in [1usize, 2] {
        let exec = format!("win_grad_w{w}_{model}");
        if rt.spec(&exec).is_err() {
            continue;
        }
        let b = build_bindings(w);
        let full = time_n(reps, || {
            rt.run(&exec, b.inner()).unwrap();
        });
        // pin the static inputs: weights + v0 (constant per job)
        let static_names: BTreeMap<String, Value> = b
            .inner()
            .iter()
            .filter(|(k, _)| {
                k.starts_with("blocks.") || k.ends_with(".v0")
            })
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        let pinned = rt.pin(&exec, &static_names).unwrap();
        let dynamic: BTreeMap<String, Value> = b
            .inner()
            .iter()
            .filter(|(k, _)| !static_names.contains_key(*k))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        let pin_t = time_n(reps, || {
            rt.run_pinned(&pinned, &dynamic).unwrap();
        });
        t.row(&[
            w.to_string(),
            fmt_f(full * 1e3, 2),
            fmt_f(pin_t * 1e3, 2),
            format!("{:.2}x", full / pin_t),
        ]);
    }
    t.print();

    // ---- quantized eval throughput ----------------------------------------
    let mut pipe2 = Pipeline::new(&art, rt, &model).unwrap();
    let mut job = QuantJob::rtn(BitSpec::w4a4());
    job.calib_sequences = 4;
    let (qm, _) = pipe2.run(&job).unwrap();
    let eval_batches = calib::eval_stream(Style::C4, 4, cfg.batch, cfg.seq);
    let toks_per_batch = (cfg.batch * cfg.seq) as f64;
    let per_batch = time_n(3, || {
        for b in &eval_batches {
            let mask = Tensor::full(&[cfg.batch, cfg.seq], 1.0);
            pipe2.lm_nll(&qm, &b.inputs(), &b.targets(), &mask).unwrap();
        }
    }) / eval_batches.len() as f64;
    let mut t = Table::new("quantized eval throughput", &["metric", "value"]);
    t.row(&["batch latency (ms)".into(), fmt_f(per_batch * 1e3, 2)]);
    t.row(&["tokens/s".into(), fmt_f(toks_per_batch / per_batch, 0)]);
    t.print();

    let stats = rt.stats();
    println!(
        "\ntotals: {} execs, {:.1}ms exec time, {:.1} MiB uploaded",
        stats.executions,
        stats.execute_ms,
        stats.upload_bytes as f64 / (1024.0 * 1024.0)
    );
}
