//! Runtime hot-path benchmark (`cargo bench --bench perf_runtime`) — the
//! §Perf instrument for the L3 layer.
//!
//! Measures, per model config:
//!   * executable compile time (one-off)
//!   * window-grad step latency (the CBD optimization inner loop)
//!   * full-upload vs pinned-weight execution (weights as persistent device
//!     buffers; only learnable tensors re-uploaded per step)
//!   * quantized-eval throughput (tokens/s through the block chain + head)
//!   * matmul GFLOP/s at {256, 512, 1024}, naive row-parallel vs the
//!     blocked/packed-panel kernels (the before/after of the PR 3 refactor;
//!     `CBQ_NAIVE_KERNELS=1` forces the naive path process-wide)
//!   * packed-domain matmul (serve from 2/4/8-bit codes, bitwise ==
//!     dequant→f32) and packed-vs-f32 window pinning: steady tokens/s,
//!     resident-bytes ratio, prefetch counters
//!   * serve-bench tokens/s over a snapshot (pool + pinned windows), at
//!     `CBQ_BENCH_DISPATCH` concurrency
//!   * token-generation decode tokens/s + per-token latency percentiles
//!     through the KV-cached continuous-batching loop
//!     (`CBQ_BENCH_MAX_NEW` / `CBQ_BENCH_GEN_REQUESTS`)
//!   * packed decode: per-bit qmatvec effective code GB/s at the active
//!     SIMD tier, plus packed-vs-f32 generation (bitwise-identical token
//!     streams, decode tokens/s ratio, packed residency)
//!
//! Besides the human-readable tables, writes a machine-readable
//! `BENCH_native.json` (path override: `CBQ_BENCH_JSON`) so the perf
//! trajectory has data points — CI's perf-smoke job asserts on it.

use std::collections::BTreeMap;
use std::time::Instant;

use cbq::calib::{self, corpus::Style};
use cbq::config::{BitSpec, QuantJob, RoundingMode};
use cbq::coordinator::Pipeline;
use cbq::json::{self, Value as J};
use cbq::report::{fmt_f, Table};
use cbq::runtime::backend::kernels;
use cbq::runtime::{self, Artifacts, Backend as _, Bindings, Value};
use cbq::serve::clock::ticks_to_secs;
use cbq::serve::scheduler::{synth_trace, Scheduler, SchedulerCfg, TraceSpec};
use cbq::serve::{
    batcher, synth_gen_trace, Batcher, EngineOptions, GenCfg, GenTraceSpec, GenerateEngine,
    LoadMode, ModelRegistry, RealClock, RowExecutor as _, ServeEngine, ServeMetrics,
};
use cbq::tensor::Tensor;

fn time_n<F: FnMut()>(n: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..n {
        f();
    }
    t0.elapsed().as_secs_f64() / n as f64
}

fn main() {
    let art = Artifacts::discover().expect("run `make artifacts` or `cbq synth` first");
    let model = std::env::var("CBQ_BENCH_MODEL").unwrap_or_else(|_| art.default_model().to_string());
    let reps: usize = std::env::var("CBQ_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(10);
    let rt = runtime::create_selected(&art, None).unwrap();
    let rt = rt.as_ref();
    let pipe = Pipeline::new(&art, rt, &model).unwrap();
    let cfg = pipe.cfg.clone();
    println!("perf_runtime on model `{model}` (d={} L={}), {reps} reps", cfg.d_model, cfg.n_layers);

    // ---- compile costs ----------------------------------------------------
    let mut t = Table::new("compile time (first use)", &["executable", "ms"]);
    for name in [
        format!("win_fwd_w1_{model}"),
        format!("win_grad_w1_{model}"),
        format!("win_grad_w2_{model}"),
        format!("lm_eval_{model}"),
    ] {
        let before = rt.stats().compile_ms;
        rt.warmup(&name).unwrap();
        let after = rt.stats().compile_ms;
        t.row(&[name, fmt_f(after - before, 1)]);
    }
    t.print();

    // ---- window-grad step latency: full upload vs pinned weights ----------
    let job = QuantJob::cbq(BitSpec::w4a4());
    let qstate = pipe.init_qstate(&pipe.fp, &job.bits, job.rank, RoundingMode::Lora);
    let batch = &calib::calibration(cfg.batch, cfg.batch, cfg.seq)[0];
    let h0 = pipe.fp.embed_tokens(&batch.inputs().data, cfg.batch, cfg.seq);

    let build_bindings = |w: usize| -> Bindings {
        let mut b = Bindings::new();
        b.set("h_in", h0.clone());
        b.set("target", Tensor::zeros(&h0.dims));
        for j in 0..w {
            Pipeline::bind_block_weights(&mut b, j, &pipe.fp.blocks[j]);
            Pipeline::bind_qblock(&mut b, j, &qstate[j], 7.0, 1.0, 1.0, false);
        }
        Pipeline::bind_globals(&mut b, 1.0, 10.0, 1e-3, 1.0, 1.0);
        b
    };

    let mut t = Table::new(
        "window-grad step latency (ms)",
        &["window", "full upload", "pinned weights", "speedup"],
    );
    for w in [1usize, 2] {
        let exec = format!("win_grad_w{w}_{model}");
        if rt.spec(&exec).is_err() {
            continue;
        }
        let b = build_bindings(w);
        let full = time_n(reps, || {
            rt.run(&exec, b.inner()).unwrap();
        });
        // pin the static inputs: weights + v0 (constant per job)
        let static_names: BTreeMap<String, Value> = b
            .inner()
            .iter()
            .filter(|(k, _)| {
                k.starts_with("blocks.") || k.ends_with(".v0")
            })
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        let pinned = rt.pin(&exec, &static_names).unwrap();
        let dynamic: BTreeMap<String, Value> = b
            .inner()
            .iter()
            .filter(|(k, _)| !static_names.contains_key(*k))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        let pin_t = time_n(reps, || {
            rt.run_pinned(&pinned, &dynamic).unwrap();
        });
        t.row(&[
            w.to_string(),
            fmt_f(full * 1e3, 2),
            fmt_f(pin_t * 1e3, 2),
            format!("{:.2}x", full / pin_t),
        ]);
    }
    t.print();

    // ---- quantized eval throughput ----------------------------------------
    let mut pipe2 = Pipeline::new(&art, rt, &model).unwrap();
    let mut job = QuantJob::rtn(BitSpec::w4a4());
    job.calib_sequences = 4;
    let (qm, _) = pipe2.run(&job).unwrap();
    let eval_batches = calib::eval_stream(Style::C4, 4, cfg.batch, cfg.seq);
    let toks_per_batch = (cfg.batch * cfg.seq) as f64;
    let per_batch = time_n(3, || {
        for b in &eval_batches {
            let mask = Tensor::full(&[cfg.batch, cfg.seq], 1.0);
            pipe2.lm_nll(&qm, &b.inputs(), &b.targets(), &mask).unwrap();
        }
    }) / eval_batches.len() as f64;
    let eval_tokens_per_s = toks_per_batch / per_batch;
    let mut t = Table::new("quantized eval throughput", &["metric", "value"]);
    t.row(&["batch latency (ms)".into(), fmt_f(per_batch * 1e3, 2)]);
    t.row(&["tokens/s".into(), fmt_f(eval_tokens_per_s, 0)]);
    t.print();

    // ---- matmul kernels: naive vs blocked, GFLOP/s ------------------------
    // the before/after of the blocked-kernel refactor; each size runs both
    // implementations on identical inputs (bitwise-equal outputs by design)
    let mut mm_rows = Vec::new();
    let mut t = Table::new(
        "matmul GFLOP/s (naive row-parallel vs blocked/packed)",
        &["size", "naive", "blocked", "speedup"],
    );
    for size in [256usize, 512, 1024] {
        let a: Vec<f32> = (0..size * size).map(|i| ((i as f32) * 0.61).sin()).collect();
        let b: Vec<f32> = (0..size * size).map(|i| ((i as f32) * 0.37).cos()).collect();
        let flops = 2.0 * (size as f64).powi(3);
        let reps = if size >= 1024 { 2 } else { 4 };
        let t_naive = time_n(reps, || {
            std::hint::black_box(kernels::matmul_naive(&a, size, size, &b, size));
        });
        let t_blocked = time_n(reps, || {
            std::hint::black_box(kernels::matmul(&a, size, size, &b, size));
        });
        let (g_naive, g_blocked) = (flops / t_naive / 1e9, flops / t_blocked / 1e9);
        t.row(&[
            size.to_string(),
            fmt_f(g_naive, 2),
            fmt_f(g_blocked, 2),
            format!("{:.2}x", t_naive / t_blocked),
        ]);
        mm_rows.push(J::obj(vec![
            ("size", J::num(size as f64)),
            ("naive_gflops", J::num(g_naive)),
            ("blocked_gflops", J::num(g_blocked)),
            ("speedup", J::num(t_naive / t_blocked)),
        ]));
    }
    t.print();

    // ---- packed-domain matmul: serve from 2/4/8-bit codes -----------------
    // qmatmul reads packed codes + scales in place; the f32 comparison runs
    // the blocked kernel over the dequantized copy of the same codes
    // (outputs are bitwise-equal by construction — asserted here too)
    let mut qmm_rows = Vec::new();
    let mut t = Table::new(
        "packed matmul (serve from codes, bitwise == dequant->f32)",
        &["bits", "f32 GFLOP/s", "packed GFLOP/s", "speedup", "weight GB/s f32->packed"],
    );
    {
        let (m, k, n) = (64usize, 512usize, 512usize);
        let a: Vec<f32> = (0..m * k).map(|i| ((i as f32) * 0.43).sin()).collect();
        let flops = 2.0 * (m * k * n) as f64;
        for bits in [2u8, 4, 8] {
            let half = 1i32 << (bits - 1);
            let codes: Vec<i32> = (0..k * n)
                .map(|i| (((i * 2654435761) >> 7) as u32 % (2 * half as u32)) as i32 - half)
                .collect();
            let s_w: Vec<f32> =
                (0..n).map(|j| 0.002 + 0.001 * ((j as f32) * 0.7).cos().abs()).collect();
            let q = kernels::QPanels::pack(&codes, k, n, bits, &s_w);
            let deq = q.dequant();
            assert_eq!(
                kernels::qmatmul(&a, m, k, &q),
                kernels::matmul(&a, m, k, &deq, n),
                "packed matmul diverged from dequant->f32 at {bits} bits"
            );
            let t_f32 = time_n(4, || {
                std::hint::black_box(kernels::matmul(&a, m, k, &deq, n));
            });
            let t_packed = time_n(4, || {
                std::hint::black_box(kernels::qmatmul(&a, m, k, &q));
            });
            let (g_f32, g_packed) = (flops / t_f32 / 1e9, flops / t_packed / 1e9);
            let f32_bytes = (k * n * 4) as f64;
            let packed_bytes = q.heap_bytes() as f64;
            // weight-stream bandwidth: bytes of B actually read per second
            let (bw_f32, bw_packed) = (f32_bytes / t_f32 / 1e9, packed_bytes / t_packed / 1e9);
            t.row(&[
                format!("w{bits}"),
                fmt_f(g_f32, 2),
                fmt_f(g_packed, 2),
                format!("{:.2}x", t_f32 / t_packed),
                format!("{:.2} -> {:.2}", bw_f32, bw_packed),
            ]);
            qmm_rows.push(J::obj(vec![
                ("bits", J::num(bits as f64)),
                ("f32_gflops", J::num(g_f32)),
                ("packed_gflops", J::num(g_packed)),
                ("speedup", J::num(t_f32 / t_packed)),
                ("f32_weight_bytes", J::num(f32_bytes)),
                ("packed_weight_bytes", J::num(packed_bytes)),
                ("f32_weight_gbps", J::num(bw_f32)),
                ("packed_weight_gbps", J::num(bw_packed)),
            ]));
        }
    }
    t.print();

    // ---- serve-bench over a snapshot (pinned windows + worker pool) -------
    let dispatch: usize = std::env::var("CBQ_BENCH_DISPATCH")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let snap_path = std::env::temp_dir().join(format!("cbq_perf_bench_{}.cbqs", std::process::id()));
    cbq::snapshot::save(&snap_path, &pipe2.cfg, &qm).unwrap();
    let mut reg = ModelRegistry::new();
    let snap = reg.load("bench", &snap_path).unwrap();
    let engine = ServeEngine::new(rt, &art, snap).unwrap();
    let requests = batcher::standard_mix(cfg.seq, 24, 6, 4);
    engine.execute(&requests[0].rows[..1]).unwrap(); // warm-up
    let (_, st_serial) = Batcher::coalescing(&engine).run(&engine, &requests).unwrap();
    let (_, st_par) = Batcher::coalescing(&engine)
        .with_dispatch(dispatch)
        .run(&engine, &requests)
        .unwrap();
    let mut t = Table::new(
        format!("serve-bench ({} requests, dispatch {dispatch})", requests.len()),
        &["mode", "tok/s", "occupancy", "in-flight", "wall"],
    );
    for (mode, st) in [("serial", &st_serial), ("concurrent", &st_par)] {
        t.row(&[
            mode.into(),
            fmt_f(st.tokens_per_s(), 0),
            format!("{:.1}%", st.occupancy() * 100.0),
            format!("{}/{}", st.peak_in_flight, st.dispatch_lanes),
            format!("{:.2}s", st.wall_seconds),
        ]);
    }
    t.print();

    // ---- metrics overhead (always-on stats layer) -------------------------
    // the hot-path cost of a ServeMetrics instance riding along must be
    // noise: run the identical batched burst with and without one attached
    // (2x each, best-of to shave scheduler jitter). CI's perf-smoke job
    // gates on `tokens_per_s_on >= 0.95 * tokens_per_s_off`.
    let best_of = |with_metrics: bool| -> f64 {
        (0..2)
            .map(|_| {
                let b = Batcher::coalescing(&engine).with_dispatch(dispatch);
                let b = if with_metrics {
                    b.with_metrics(std::sync::Arc::new(ServeMetrics::new()))
                } else {
                    b
                };
                let (_, st) = b.run(&engine, &requests).unwrap();
                st.tokens_per_s()
            })
            .fold(0.0f64, f64::max)
    };
    let tokens_per_s_off = best_of(false);
    let tokens_per_s_on = best_of(true);
    let overhead_ratio = tokens_per_s_on / tokens_per_s_off.max(1e-9);
    println!(
        "metrics overhead: {tokens_per_s_on:.0} tok/s with metrics vs {tokens_per_s_off:.0} \
         without ({overhead_ratio:.3}x)"
    );

    // ---- mmap vs eager: cold start + steady state -------------------------
    // cold start = registry load + engine bind + first response (the
    // time-to-first-response a serving box pays after a restart); steady
    // state = batched tokens/s once windows are faulted in. The mmap
    // engine runs with a 1-window residency budget — worst case for
    // throughput, best case for memory — and its responses are asserted
    // bitwise-identical to the eager engine's.
    let one_row = &requests[0].rows[..1];
    let t0 = Instant::now();
    let mut reg_e = ModelRegistry::new();
    let snap_e = reg_e.load_with("mm-eager", &snap_path, LoadMode::Eager).unwrap();
    let eager_engine = ServeEngine::new(rt, &art, snap_e).unwrap();
    eager_engine.execute(one_row).unwrap();
    let cold_eager_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let mut reg_m = ModelRegistry::new();
    let snap_m = reg_m.load_with("mm-mmap", &snap_path, LoadMode::Mmap).unwrap();
    let mmap_engine = ServeEngine::with_options(
        rt,
        &art,
        snap_m,
        // f32 pinning: this section measures the dequantize-at-fault path;
        // the packed comparison below has its own engines
        EngineOptions { resident_windows: Some(1), resident_bytes: None, packed: false },
    )
    .unwrap();
    mmap_engine.execute(one_row).unwrap();
    let cold_mmap_s = t0.elapsed().as_secs_f64();

    let (resp_e, st_eager) =
        Batcher::coalescing(&eager_engine).run(&eager_engine, &requests).unwrap();
    let (resp_m, st_mmap) =
        Batcher::coalescing(&mmap_engine).run(&mmap_engine, &requests).unwrap();
    let mmap_identical = resp_e == resp_m;
    let res_m = mmap_engine.residency();
    let res_e = eager_engine.residency();
    let mut t = Table::new(
        "mmap vs eager serving (cold start + steady state)",
        &["mode", "cold start (ms)", "steady tok/s", "resident bytes"],
    );
    t.row(&[
        "eager".into(),
        fmt_f(cold_eager_s * 1e3, 1),
        fmt_f(st_eager.tokens_per_s(), 0),
        format!("{}", res_e.resident_bytes),
    ]);
    t.row(&[
        "mmap (1 window)".into(),
        fmt_f(cold_mmap_s * 1e3, 1),
        fmt_f(st_mmap.tokens_per_s(), 0),
        format!("{} peak", res_m.peak_bytes),
    ]);
    t.print();
    println!(
        "mmap responses identical: {}; {} faults / {} hits / {} evictions",
        if mmap_identical { "yes" } else { "NO — serving bug" },
        res_m.faults,
        res_m.hits,
        res_m.evictions
    );

    // ---- packed vs f32 window pinning (mmap steady state) -----------------
    // two lazy engines over the same mapping, unlimited residency: one pins
    // dequantized f32 weights, one pins the packed codes + scales in place.
    // Responses must be bitwise-identical; the resident-bytes ratio is the
    // headline figure (~(32/bits)x on the weight-dominated records, more
    // once the f32 path's v0 warm-start copies are counted).
    let mut reg_pf = ModelRegistry::new();
    let snap_pf = reg_pf.load_with("pk-f32", &snap_path, LoadMode::Mmap).unwrap();
    let f32_engine = ServeEngine::with_options(
        rt,
        &art,
        snap_pf,
        EngineOptions { resident_windows: None, resident_bytes: None, packed: false },
    )
    .unwrap();
    let mut reg_pp = ModelRegistry::new();
    let snap_pp = reg_pp.load_with("pk-packed", &snap_path, LoadMode::Mmap).unwrap();
    let packed_engine = ServeEngine::with_options(
        rt,
        &art,
        snap_pp,
        EngineOptions { resident_windows: None, resident_bytes: None, packed: true },
    )
    .unwrap();
    f32_engine.execute(one_row).unwrap();
    packed_engine.execute(one_row).unwrap();
    let (resp_f, st_f32p) = Batcher::coalescing(&f32_engine).run(&f32_engine, &requests).unwrap();
    let (resp_p, st_packed) =
        Batcher::coalescing(&packed_engine).run(&packed_engine, &requests).unwrap();
    let packed_identical = resp_f == resp_p;
    let res_f = f32_engine.residency();
    let res_p = packed_engine.residency();
    let resident_ratio = res_f.resident_bytes as f64 / (res_p.resident_bytes as f64).max(1.0);
    let mut t = Table::new(
        "packed vs f32 window pinning (mmap, all windows resident)",
        &["pinning", "steady tok/s", "resident bytes", "prefetches (hit)"],
    );
    t.row(&[
        "f32".into(),
        fmt_f(st_f32p.tokens_per_s(), 0),
        format!("{}", res_f.resident_bytes),
        format!("{} ({})", res_f.prefetches, res_f.prefetch_hits),
    ]);
    t.row(&[
        if packed_engine.is_packed() { "packed".into() } else { "packed (UNAVAILABLE)".to_string() },
        fmt_f(st_packed.tokens_per_s(), 0),
        format!("{}", res_p.resident_bytes),
        format!("{} ({})", res_p.prefetches, res_p.prefetch_hits),
    ]);
    t.print();
    println!(
        "packed responses identical: {}; resident bytes {:.2}x smaller",
        if packed_identical { "yes (packed == f32, bitwise)" } else { "NO — packed kernel bug" },
        resident_ratio,
    );

    // ---- live arrival loop (priority scheduler over the engine) -----------
    // real clock: arrivals are slept to, service time is measured — this is
    // the honest live-loop tokens/s and per-class latency figure. (Replay
    // determinism is the simulated clock's job and is asserted by
    // tests/scheduler.rs + `cbq serve-bench --live --verify-determinism`.)
    let trace_seed: u64 = std::env::var("CBQ_BENCH_TRACE_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(7);
    let spec = TraceSpec {
        seed: trace_seed,
        requests: 48,
        mean_gap_ticks: 500, // ~2000 req/s offered: keeps the loop saturated
        seq: cfg.seq,
        vocab: cfg.vocab as u32,
        priorities: true,
    };
    let trace = synth_trace(&spec);
    let live_clock = RealClock::new();
    let sched = Scheduler::new(&live_clock, SchedulerCfg { dispatch, ..Default::default() });
    let live = sched.run(&engine, &trace).unwrap();
    let mut t = Table::new(
        format!("live arrival loop ({} requests, seed {trace_seed}, dispatch {dispatch})", trace.len()),
        &["class", "done", "q p99 (ms)", "s p99 (ms)"],
    );
    for c in &live.stats.class_lat {
        t.row(&[
            c.class.clone(),
            c.completed.to_string(),
            fmt_f(c.queue_p99_s * 1e3, 2),
            fmt_f(c.service_p99_s * 1e3, 2),
        ]);
    }
    t.print();
    println!(
        "live loop: {:.0} tokens/s over {} cycles ({} admitted / {} rejected)",
        live.stats.tokens_per_s(),
        live.cycles,
        live.stats.requests - live.stats.rejected,
        live.stats.rejected
    );

    // ---- token generation (KV-cached decode + continuous batching) --------
    // real clock, honest decode tokens/s and per-token latency percentiles;
    // replay determinism is the simulated clock's job and is asserted by
    // tests/generate.rs + `cbq serve-bench --generate --verify-determinism`.
    let max_new: usize = std::env::var("CBQ_BENCH_MAX_NEW")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let gen_requests: usize = std::env::var("CBQ_BENCH_GEN_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);
    let gen = GenerateEngine::new(&engine).unwrap();
    let gen_trace = synth_gen_trace(&GenTraceSpec {
        requests: gen_requests,
        mean_gap: 500,
        seed: trace_seed,
        vocab: cfg.vocab,
        max_prompt: (cfg.seq / 2).max(1),
        max_new_tokens: max_new,
    });
    let gen_cfg = GenCfg { max_new_tokens: max_new, dispatch, ..Default::default() };
    gen.decode_reference(&gen_trace[0].request.prompt, 1).unwrap(); // warm-up
    let gen_clock = RealClock::new();
    let (_, gen_stats) = gen.run(&gen_trace, &gen_cfg, &gen_clock).unwrap();
    let mut t = Table::new(
        format!(
            "token generation ({gen_requests} requests, max-new {max_new}, dispatch {dispatch})"
        ),
        &["metric", "value"],
    );
    t.row(&["decode tokens/s".into(), fmt_f(gen_stats.tokens_per_s, 0)]);
    t.row(&["tokens".into(), gen_stats.tokens.to_string()]);
    t.row(&["decode steps".into(), gen_stats.decode_steps.to_string()]);
    t.row(&["peak batch".into(), gen_stats.peak_active.to_string()]);
    t.row(&["tok p50 (ms)".into(), fmt_f(ticks_to_secs(gen_stats.tok_p50) * 1e3, 2)]);
    t.row(&["tok p95 (ms)".into(), fmt_f(ticks_to_secs(gen_stats.tok_p95) * 1e3, 2)]);
    t.row(&["tok p99 (ms)".into(), fmt_f(ticks_to_secs(gen_stats.tok_p99) * 1e3, 2)]);
    t.print();

    // ---- packed decode (generation straight from the codes) ---------------
    // decode-shaped (rows == 1) per-bit qmatvec microbench — effective
    // *code* GB/s is the number that bounds memory-bound decode — then an
    // end-to-end generate run over the packed-vs-f32 engines from above:
    // token streams must be bitwise-identical, packed decode tokens/s vs
    // f32 is the headline ratio.
    let mut qmv_rows = Vec::new();
    let mut t = Table::new(
        format!("packed matvec (decode hot path, SIMD tier {})", kernels::simd_tier().name()),
        &["bits", "f32 GFLOP/s", "packed GFLOP/s", "code GB/s"],
    );
    {
        let (k, n) = (512usize, 512usize);
        let a: Vec<f32> = (0..k).map(|i| ((i as f32) * 0.43).sin()).collect();
        let flops = 2.0 * (k * n) as f64;
        for bits in [2u8, 4, 8] {
            let half = 1i32 << (bits - 1);
            let codes: Vec<i32> = (0..k * n)
                .map(|i| (((i * 2654435761) >> 7) as u32 % (2 * half as u32)) as i32 - half)
                .collect();
            let s_w: Vec<f32> =
                (0..n).map(|j| 0.002 + 0.001 * ((j as f32) * 0.7).cos().abs()).collect();
            let q = kernels::QPanels::pack(&codes, k, n, bits, &s_w);
            let deq = q.dequant();
            assert_eq!(
                kernels::qmatvec(&a, k, &q),
                kernels::qmatmul(&a, 1, k, &q),
                "qmatvec diverged from the qmatmul row at {bits} bits"
            );
            assert_eq!(
                kernels::qmatvec(&a, k, &q),
                kernels::matmul(&a, 1, k, &deq, n),
                "qmatvec diverged from dequant->f32 at {bits} bits"
            );
            let t_f32 = time_n(64, || {
                std::hint::black_box(kernels::matmul(&a, 1, k, &deq, n));
            });
            let t_packed = time_n(64, || {
                std::hint::black_box(kernels::qmatvec(&a, k, &q));
            });
            let code_gbps = q.code_bytes() as f64 / t_packed / 1e9;
            t.row(&[
                format!("w{bits}"),
                fmt_f(flops / t_f32 / 1e9, 2),
                fmt_f(flops / t_packed / 1e9, 2),
                fmt_f(code_gbps, 2),
            ]);
            qmv_rows.push(J::obj(vec![
                ("bits", J::num(bits as f64)),
                ("f32_gflops", J::num(flops / t_f32 / 1e9)),
                ("packed_gflops", J::num(flops / t_packed / 1e9)),
                ("code_bytes", J::num(q.code_bytes() as f64)),
                ("code_gbps", J::num(code_gbps)),
            ]));
        }
    }
    t.print();

    let gen_f32d = GenerateEngine::new(&f32_engine).unwrap();
    let gen_pkd = GenerateEngine::new(&packed_engine).unwrap();
    gen_f32d.decode_reference(&gen_trace[0].request.prompt, 1).unwrap(); // warm-up
    gen_pkd.decode_reference(&gen_trace[0].request.prompt, 1).unwrap();
    let cf = RealClock::new();
    let (out_f32d, gstats_f32d) = gen_f32d.run(&gen_trace, &gen_cfg, &cf).unwrap();
    let cp = RealClock::new();
    let (out_pkd, gstats_pkd) = gen_pkd.run(&gen_trace, &gen_cfg, &cp).unwrap();
    // under the real clock emission ticks differ run-to-run; the invariant
    // is the token content per request
    let streams_of = |outs: &[cbq::serve::GenOutcome]| -> Vec<(usize, bool, Vec<i32>)> {
        outs.iter().map(|o| (o.seq, o.rejected, o.tokens.clone())).collect()
    };
    let decode_identical = streams_of(&out_f32d) == streams_of(&out_pkd);
    let decode_ratio = gstats_pkd.tokens_per_s / gstats_f32d.tokens_per_s.max(1e-9);
    let res_fd = f32_engine.residency();
    let res_pd = packed_engine.residency();
    let mut t = Table::new(
        "packed vs f32 decode (token generation)",
        &["path", "decode tok/s", "resident bytes", "prefetches (hit)"],
    );
    t.row(&[
        "f32".into(),
        fmt_f(gstats_f32d.tokens_per_s, 0),
        format!("{}", res_fd.resident_bytes),
        format!("{} ({})", res_fd.prefetches, res_fd.prefetch_hits),
    ]);
    t.row(&[
        if packed_engine.is_packed() { "packed".into() } else { "packed (UNAVAILABLE)".to_string() },
        fmt_f(gstats_pkd.tokens_per_s, 0),
        format!("{}", res_pd.resident_bytes),
        format!("{} ({})", res_pd.prefetches, res_pd.prefetch_hits),
    ]);
    t.print();
    println!(
        "packed decode streams identical: {}; {:.2}x f32 decode tokens/s",
        if decode_identical { "yes (packed == f32, bitwise)" } else { "NO — packed decode bug" },
        decode_ratio,
    );

    std::fs::remove_file(&snap_path).ok();
    let stats = rt.stats();
    println!(
        "\ntotals: {} execs, {:.1}ms exec time, {:.1} MiB uploaded",
        stats.executions,
        stats.execute_ms,
        stats.upload_bytes as f64 / (1024.0 * 1024.0)
    );

    // ---- machine-readable record ------------------------------------------
    let out_path =
        std::env::var("CBQ_BENCH_JSON").unwrap_or_else(|_| "BENCH_native.json".to_string());
    let doc = J::obj(vec![
        ("bench", J::str("perf_runtime")),
        ("model", J::str(model.clone())),
        ("backend", J::str(rt.name())),
        ("threads", J::num(kernels::num_threads() as f64)),
        (
            "naive_kernels_forced",
            J::Bool(std::env::var("CBQ_NAIVE_KERNELS").map(|v| v == "1").unwrap_or(false)),
        ),
        ("matmul", J::arr(mm_rows)),
        ("eval_tokens_per_s", J::num(eval_tokens_per_s)),
        (
            "serve",
            J::obj(vec![
                ("requests", J::num(requests.len() as f64)),
                ("dispatch", J::num(dispatch as f64)),
                ("serial_tokens_per_s", J::num(st_serial.tokens_per_s())),
                ("concurrent_tokens_per_s", J::num(st_par.tokens_per_s())),
                ("occupancy", J::num(st_par.occupancy())),
                ("peak_in_flight", J::num(st_par.peak_in_flight as f64)),
                ("lane_occupancy", J::num(st_par.lane_occupancy())),
            ]),
        ),
        (
            "metrics",
            J::obj(vec![
                ("enabled", J::Bool(true)),
                ("tokens_per_s_on", J::num(tokens_per_s_on)),
                ("tokens_per_s_off", J::num(tokens_per_s_off)),
                ("overhead_ratio", J::num(overhead_ratio)),
            ]),
        ),
        (
            "mmap",
            J::obj(vec![
                ("cold_start_eager_s", J::num(cold_eager_s)),
                ("cold_start_mmap_s", J::num(cold_mmap_s)),
                ("steady_eager_tokens_per_s", J::num(st_eager.tokens_per_s())),
                ("steady_mmap_tokens_per_s", J::num(st_mmap.tokens_per_s())),
                ("responses_identical", J::Bool(mmap_identical)),
                ("resident_windows_budget", J::num(1.0)),
                ("mmap_peak_resident_bytes", J::num(res_m.peak_bytes as f64)),
                ("eager_resident_bytes", J::num(res_e.resident_bytes as f64)),
                ("mmap_faults", J::num(res_m.faults as f64)),
                ("mmap_evictions", J::num(res_m.evictions as f64)),
            ]),
        ),
        (
            "packed",
            J::obj(vec![
                ("enabled", J::Bool(packed_engine.is_packed())),
                ("qmatmul", J::arr(qmm_rows)),
                ("steady_f32_tokens_per_s", J::num(st_f32p.tokens_per_s())),
                ("steady_packed_tokens_per_s", J::num(st_packed.tokens_per_s())),
                ("responses_identical", J::Bool(packed_identical)),
                ("f32_resident_bytes", J::num(res_f.resident_bytes as f64)),
                ("packed_resident_bytes", J::num(res_p.resident_bytes as f64)),
                ("resident_ratio", J::num(resident_ratio)),
                ("f32_prefetches", J::num(res_f.prefetches as f64)),
                ("packed_prefetches", J::num(res_p.prefetches as f64)),
                ("packed_prefetch_hits", J::num(res_p.prefetch_hits as f64)),
            ]),
        ),
        (
            "live",
            J::obj(vec![
                ("trace_seed", J::num(trace_seed as f64)),
                ("requests", J::num(trace.len() as f64)),
                ("dispatch", J::num(dispatch as f64)),
                ("priorities", J::Bool(true)),
                ("cycles", J::num(live.cycles as f64)),
                ("admitted", J::num((live.stats.requests - live.stats.rejected) as f64)),
                ("rejected", J::num(live.stats.rejected as f64)),
                ("tokens_per_s", J::num(live.stats.tokens_per_s())),
                ("occupancy", J::num(live.stats.occupancy())),
                (
                    "classes",
                    J::arr(
                        live.stats
                            .class_lat
                            .iter()
                            .map(|c| {
                                J::obj(vec![
                                    ("class", J::str(c.class.clone())),
                                    ("submitted", J::num(c.submitted as f64)),
                                    ("completed", J::num(c.completed as f64)),
                                    ("rejected", J::num(c.rejected as f64)),
                                    ("queue_p50_s", J::num(c.queue_p50_s)),
                                    ("queue_p95_s", J::num(c.queue_p95_s)),
                                    ("queue_p99_s", J::num(c.queue_p99_s)),
                                    ("service_p50_s", J::num(c.service_p50_s)),
                                    ("service_p95_s", J::num(c.service_p95_s)),
                                    ("service_p99_s", J::num(c.service_p99_s)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "generate",
            J::obj(vec![
                ("trace_seed", J::num(trace_seed as f64)),
                ("max_new_tokens", J::num(max_new as f64)),
                ("clock", J::str("real")),
                ("requests", J::num(gen_stats.requests as f64)),
                ("completed", J::num(gen_stats.completed as f64)),
                ("rejected", J::num(gen_stats.rejected as f64)),
                ("decode_steps", J::num(gen_stats.decode_steps as f64)),
                ("tokens", J::num(gen_stats.tokens as f64)),
                ("decode_tokens_per_s", J::num(gen_stats.tokens_per_s)),
                ("tok_p50_s", J::num(ticks_to_secs(gen_stats.tok_p50))),
                ("tok_p95_s", J::num(ticks_to_secs(gen_stats.tok_p95))),
                ("tok_p99_s", J::num(ticks_to_secs(gen_stats.tok_p99))),
                ("wall_seconds", J::num(ticks_to_secs(gen_stats.wall_ticks))),
                ("dispatch", J::num(gen_stats.dispatch_lanes as f64)),
                ("peak_active", J::num(gen_stats.peak_active as f64)),
            ]),
        ),
        (
            "packed_decode",
            J::obj(vec![
                ("enabled", J::Bool(packed_engine.is_packed())),
                ("simd", J::str(kernels::simd_tier().name())),
                ("qmatvec", J::arr(qmv_rows)),
                ("f32_decode_tokens_per_s", J::num(gstats_f32d.tokens_per_s)),
                ("packed_decode_tokens_per_s", J::num(gstats_pkd.tokens_per_s)),
                ("decode_ratio", J::num(decode_ratio)),
                ("streams_identical", J::Bool(decode_identical)),
                ("f32_resident_bytes", J::num(res_fd.resident_bytes as f64)),
                ("packed_resident_bytes", J::num(res_pd.resident_bytes as f64)),
                ("prefetches", J::num(res_pd.prefetches as f64)),
                ("prefetch_hits", J::num(res_pd.prefetch_hits as f64)),
            ]),
        ),
    ]);
    std::fs::write(&out_path, json::dump(&doc)).unwrap();
    println!("wrote {out_path}");
}
