//! Paper-figure regeneration harness (`cargo bench --bench figures`).
//!
//! Figure 1 (a/b/c): Hessian dependency analysis — off-diagonal mass grows
//! as bits shrink (the paper's Sec. 2 motivation).
//! Figure 3: weight/activation outlier distributions before/after CFP.
//!
//! Output: ASCII heatmaps + histograms to stdout, CSV matrices to
//! `bench_out/` for external plotting.

use std::fs;
use std::time::Instant;

use cbq::calib;
use cbq::cfp;
use cbq::config::{BitSpec, PreprocMethod, QuantJob};
use cbq::coordinator::Pipeline;
use cbq::hessian::{offdiag_ratio, HessianProbe};
use cbq::model_state::ActStats;
use cbq::report::{heatmap, magnitude_histogram, matrix_csv, Table};
use cbq::runtime::{self, Artifacts};

fn out_dir() -> std::path::PathBuf {
    let p = std::path::PathBuf::from("bench_out");
    fs::create_dir_all(&p).ok();
    p
}

/// Figure 1(b): inter-block scale Hessian at W8 / W4 / W2, plus the
/// summary off-diagonal-mass trend; 1(a): intra-layer weight Hessian block;
/// 1(c): pairwise loss surface over two adjacent blocks' scales.
fn fig1(art: &Artifacts, model: &str) {
    let rt = runtime::create_selected(art, None).unwrap();
    let pipe = Pipeline::new(art, &rt, model).unwrap();
    let mut trend = Table::new(
        format!("Fig. 1 — dependency strength vs bits (`{model}`)"),
        &["bits", "inter-block offdiag ratio", "intra-layer offdiag ratio"],
    );
    for bits in [8u8, 4, 2] {
        let probe = HessianProbe::new(&pipe, BitSpec::new(bits, 16)).unwrap();
        let inter = probe.inter_block_hessian(0.05).unwrap();
        println!("{}", heatmap(&format!("Fig 1b: inter-block scale Hessian, W{bits}"), &inter));
        fs::write(out_dir().join(format!("fig1b_w{bits}.csv")), matrix_csv(&inter)).unwrap();

        let intra = probe.intra_layer_hessian(0, "wq", 12, 0.02).unwrap();
        println!("{}", heatmap(&format!("Fig 1a: intra-layer weight Hessian (block0.wq), W{bits}"), &intra));
        fs::write(out_dir().join(format!("fig1a_w{bits}.csv")), matrix_csv(&intra)).unwrap();

        trend.row(&[
            format!("W{bits}"),
            format!("{:.4}", offdiag_ratio(&inter)),
            format!("{:.4}", offdiag_ratio(&intra)),
        ]);
    }
    trend.print();
    println!("expected shape: both ratios grow as bits shrink (Sec. 2)");

    // 1(c): loss surface over joint scale multipliers of blocks 0 and 1
    let probe = HessianProbe::new(&pipe, BitSpec::new(4, 16)).unwrap();
    let grid: Vec<f32> = (0..7).map(|i| 0.7 + 0.1 * i as f32).collect();
    let surface = probe.pairwise_loss_surface(0, 1, &grid).unwrap();
    println!("{}", heatmap("Fig 1c: loss vs (scale b0, scale b1) @ W4", &surface));
    fs::write(out_dir().join("fig1c.csv"), matrix_csv(&surface)).unwrap();
}

/// Figure 3: outlier distributions in weights and activations, before and
/// after CFP pre-processing.
fn fig3(art: &Artifacts, model: &str) {
    let rt = runtime::create_selected(art, None).unwrap();
    let mut pipe = Pipeline::new(art, &rt, model).unwrap();
    let calib_set = calib::calibration(8, pipe.cfg.batch, pipe.cfg.seq);
    let fp_hidden = pipe.fp_hidden_states(&calib_set).unwrap();
    let stats: ActStats = pipe.capture_stats(&pipe.fp.clone(), &calib_set, &fp_hidden).unwrap();

    // weights: block 0 wup (one of the injected weight-outlier carriers)
    let w = &pipe.fp.blocks[0].linears["wup"];
    println!("{}", magnitude_histogram("Fig 3: |W| block0.wup BEFORE CFP", &w.data, 16));
    let det = cfp::detect_default(&w.data);
    println!(
        "CFP weight detection: {} candidates, {} outliers, threshold {:?}, reserved max {:.4}",
        det.n_candidates, det.n_outliers, det.threshold, det.reserved_max
    );

    // activations: per-channel maxima of the attn input of block 0
    let maxima = stats.max_of(0, "wq").to_vec();
    println!("{}", magnitude_histogram("Fig 3: act channel max |X| block0.attn_in BEFORE CFP", &maxima, 16));

    // run CFP + re-capture to show the post-state
    let mut job = QuantJob::rtn(BitSpec::w4a4());
    job.preproc = PreprocMethod::CfpFull;
    job.calib_sequences = 8;
    let (m, summary) = pipe.run(&job).unwrap();
    println!(
        "CFP applied: {} weights truncated, {} activation channels scaled",
        summary.preproc_weights_truncated, summary.preproc_channels_scaled
    );
    let w_after = &m.params.blocks[0].linears["wup"];
    println!("{}", magnitude_histogram("Fig 3: |W| block0.wup AFTER CFP (then RTN)", &w_after.data, 16));

    let stats_after = {
        // capture on the preprocessed weights (before fake-quant would be
        // ideal; the RTN grid only coarsens magnitudes slightly)
        pipe.capture_stats(&m.params, &calib_set, &fp_hidden).unwrap()
    };
    let maxima_after = stats_after.max_of(0, "wq").to_vec();
    println!("{}", magnitude_histogram("Fig 3: act channel max |X| AFTER CFP", &maxima_after, 16));
}

fn main() {
    let art = Artifacts::discover().expect("run `make artifacts` or `cbq synth` first");
    let model =
        std::env::var("CBQ_BENCH_MODEL").unwrap_or_else(|_| art.model_or_default("t").to_string());
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let run_all = args.is_empty();
    let t0 = Instant::now();
    if run_all || args.iter().any(|a| a == "fig1") {
        fig1(&art, &model);
    }
    if run_all || args.iter().any(|a| a == "fig3") {
        fig3(&art, &model);
    }
    println!("\n[figures took {:.1}s; CSVs in bench_out/]", t0.elapsed().as_secs_f64());
}
