//! Paper-table regeneration harness (`cargo bench --bench tables`).
//!
//! One function per table of the CBQ paper's evaluation; each prints the
//! same rows the paper reports, measured on this repo's testbed (synthetic
//! corpora + build-time-pretrained models — see DESIGN.md §Substitutions).
//! Absolute numbers differ from the paper's A100 runs; the *shape* (who
//! wins, by roughly what factor, where the crossovers fall) is the
//! reproduction target, recorded in EXPERIMENTS.md.
//!
//! Select tables:   cargo bench --bench tables -- table2 table5
//! Scale knobs:     CBQ_BENCH_MODEL=s CBQ_BENCH_CALIB=32 CBQ_BENCH_EVAL=16
//!
//! Defaults run every table on the `t` model in a few minutes.

use std::time::Instant;

use cbq::calib::corpus::Style;
use cbq::config::{BitSpec, Method, PreprocMethod, QuantJob, RoundingMode};
use cbq::coordinator::Pipeline;
use cbq::report::{fmt_f, Table};
use cbq::runtime::{self, Artifacts, Backend};

struct Bench {
    art: Artifacts,
    model: String,
    calib: usize,
    eval_batches: usize,
    items: usize,
    epochs: usize,
}

fn envu(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

impl Bench {
    fn new() -> Self {
        let art = Artifacts::discover().expect("run `make artifacts` or `cbq synth` first");
        let default_model = art.model_or_default("t").to_string();
        Self {
            art,
            model: std::env::var("CBQ_BENCH_MODEL").unwrap_or(default_model),
            calib: envu("CBQ_BENCH_CALIB", 32),
            eval_batches: envu("CBQ_BENCH_EVAL", 8),
            items: envu("CBQ_BENCH_ITEMS", 16),
            epochs: envu("CBQ_BENCH_EPOCHS", 8),
        }
    }

    fn rt(&self) -> Box<dyn Backend> {
        runtime::create_selected(&self.art, None).unwrap()
    }

    fn pipe<'a>(&'a self, rt: &'a dyn Backend) -> Pipeline<'a> {
        Pipeline::new(&self.art, rt, &self.model).unwrap()
    }

    fn job(&self, mut j: QuantJob) -> QuantJob {
        j.calib_sequences = self.calib;
        j.epochs = self.epochs;
        j
    }

    /// quantize + ppl on both corpora; returns (c4, wiki, quant_s, summary)
    fn run_ppl(
        &self,
        pipe: &mut Pipeline,
        job: &QuantJob,
    ) -> (f64, f64, f64, cbq::coordinator::QuantSummary) {
        let (m, s) = pipe.run(job).unwrap();
        let c4 = pipe.perplexity(&m, Style::C4, self.eval_batches).unwrap();
        let wiki = pipe.perplexity(&m, Style::Wiki, self.eval_batches).unwrap();
        (c4, wiki, s.quant_seconds, s)
    }
}

fn star(bits: &BitSpec, n_layers: usize) -> BitSpec {
    let _ = bits;
    BitSpec::w2a16_star(n_layers)
}

// ---------------------------------------------------------------------------

/// Table 1: zero-shot accuracy across methods x bit settings.
fn table1(b: &Bench) {
    let rt = b.rt();
    let mut pipe = b.pipe(&rt);
    let n_layers = pipe.cfg.n_layers;
    let settings: Vec<(&str, BitSpec)> = vec![
        ("W4A16", BitSpec::w4a16()),
        ("W2A16", BitSpec::w2a16()),
        ("W4A8", BitSpec::w4a8()),
        ("W4A4", BitSpec::w4a4()),
    ];
    let mut t = Table::new(
        format!("Table 1 — zero-shot accuracy (%), model `{}`", b.model),
        &["#Bits", "Method", "TopicMatch", "CountRun", "Perturbed", "Shifted",
          "Mutual MRR/R@1/R@2"],
    );
    let fp = pipe.fp_model();
    let r = pipe.zero_shot(&fp, b.items).unwrap();
    t.row(&["FP".into(), "-".into(),
        fmt_f(r.accuracy["TopicMatch"] * 100.0, 1),
        fmt_f(r.accuracy["CountRun"] * 100.0, 1),
        fmt_f(r.accuracy["Perturbed"] * 100.0, 1),
        fmt_f(r.accuracy["Shifted"] * 100.0, 1),
        format!("{}/{}/{}", fmt_f(r.mrr * 100.0, 1), fmt_f(r.recall1 * 100.0, 1),
                fmt_f(r.recall2 * 100.0, 1))]);
    for (label, bits) in &settings {
        let mut jobs: Vec<(String, QuantJob)> = vec![
            ("GPTQ".into(), b.job(QuantJob::gptq(bits.clone()))),
            ("OmniQ-like".into(), b.job(QuantJob::omniquant_like(bits.clone()))),
            ("CBQ".into(), b.job(QuantJob::cbq(bits.clone()))),
        ];
        if *label == "W2A16" {
            jobs.push(("CBQ*".into(), b.job(QuantJob::cbq(star(bits, n_layers)))));
        }
        for (name, job) in jobs {
            let (m, _) = pipe.run(&job).unwrap();
            let r = pipe.zero_shot(&m, b.items).unwrap();
            t.row(&[label.to_string(), name,
                fmt_f(r.accuracy["TopicMatch"] * 100.0, 1),
                fmt_f(r.accuracy["CountRun"] * 100.0, 1),
                fmt_f(r.accuracy["Perturbed"] * 100.0, 1),
                fmt_f(r.accuracy["Shifted"] * 100.0, 1),
                format!("{}/{}/{}", fmt_f(r.mrr * 100.0, 1),
                        fmt_f(r.recall1 * 100.0, 1), fmt_f(r.recall2 * 100.0, 1))]);
        }
    }
    t.print();
}

/// Table 2 (+ Table 13 columns): perplexity across methods x bit settings.
fn table2(b: &Bench) {
    let rt = b.rt();
    let mut pipe = b.pipe(&rt);
    let n_layers = pipe.cfg.n_layers;
    let mut t = Table::new(
        format!("Table 2 — perplexity, model `{}`", b.model),
        &["#Bits", "Method", "synth-c4", "synth-wiki"],
    );
    let fp = pipe.fp_model();
    t.row(&["FP".into(), "-".into(),
        fmt_f(pipe.perplexity(&fp, Style::C4, b.eval_batches).unwrap(), 2),
        fmt_f(pipe.perplexity(&fp, Style::Wiki, b.eval_batches).unwrap(), 2)]);
    let rows: Vec<(&str, &str, QuantJob)> = vec![
        ("W4A16", "RTN", b.job(QuantJob::rtn(BitSpec::w4a16()))),
        ("W4A16", "GPTQ", b.job(QuantJob::gptq(BitSpec::w4a16()))),
        ("W4A16", "OmniQ-like", b.job(QuantJob::omniquant_like(BitSpec::w4a16()))),
        ("W4A16", "CBQ", b.job(QuantJob::cbq(BitSpec::w4a16()))),
        ("W2A16", "RTN", b.job(QuantJob::rtn(BitSpec::w2a16()))),
        ("W2A16", "GPTQ", b.job(QuantJob::gptq(BitSpec::w2a16()))),
        ("W2A16", "OmniQ-like", b.job(QuantJob::omniquant_like(BitSpec::w2a16()))),
        ("W2A16", "CBQ", b.job(QuantJob::cbq(BitSpec::w2a16()))),
        ("W2A16", "CBQ*", b.job(QuantJob::cbq(BitSpec::w2a16_star(n_layers)))),
        ("W4A8", "OmniQ-like", b.job(QuantJob::omniquant_like(BitSpec::w4a8()))),
        ("W4A8", "CBQ", b.job(QuantJob::cbq(BitSpec::w4a8()))),
        ("W4A4", "OmniQ-like", b.job(QuantJob::omniquant_like(BitSpec::w4a4()))),
        ("W4A4", "CBQ", b.job(QuantJob::cbq(BitSpec::w4a4()))),
    ];
    for (bits, name, job) in rows {
        let (c4, wiki, _, _) = b.run_ppl(&mut pipe, &job);
        t.row(&[bits.into(), name.into(), fmt_f(c4, 2), fmt_f(wiki, 2)]);
    }
    t.print();
}

/// Table 3a / Table 10: CFP vs baseline pre-processors, +- CBQ-Recon, W4A4.
fn table3a(b: &Bench) {
    let rt = b.rt();
    let mut pipe = b.pipe(&rt);
    let methods = [
        PreprocMethod::None,
        PreprocMethod::Omse,
        PreprocMethod::Percentile,
        PreprocMethod::OutlierSuppression,
        PreprocMethod::SmoothQuant,
        PreprocMethod::CfpActivation,
        PreprocMethod::CfpFull,
    ];
    let mut t = Table::new(
        format!("Table 3a — outlier pre-processing ablation (W4A4, `{}`)", b.model),
        &["Pre-processing", "Recon", "ppl c4", "ppl wiki"],
    );
    for recon in [false, true] {
        for pm in methods {
            let mut job = if recon {
                b.job(QuantJob::cbq(BitSpec::w4a4()))
            } else {
                b.job(QuantJob::rtn(BitSpec::w4a4()))
            };
            job.preproc = pm;
            let (c4, wiki, _, _) = b.run_ppl(&mut pipe, &job);
            t.row(&[pm.name().into(),
                if recon { "+CBQ-Recon" } else { "-" }.into(),
                fmt_f(c4, 2), fmt_f(wiki, 2)]);
        }
    }
    t.print();
}

/// Table 3b: rounding ablation — none vs dense AdaRound vs LoRA-Rounding.
fn table3b(b: &Bench) {
    let rt = b.rt();
    let mut pipe = b.pipe(&rt);
    let e = b.epochs;
    let rows: Vec<(&str, RoundingMode, usize)> = vec![
        ("w/o Rounding", RoundingMode::Nearest, e),
        ("w/ Dense AdaRound", RoundingMode::DenseAdaRound, e),
        ("w/ LoRA-Rounding", RoundingMode::Lora, e),
        ("w/ LoRA-Rounding (2x ep)", RoundingMode::Lora, 2 * e),
    ];
    let mut t = Table::new(
        format!("Table 3b — LoRA-Rounding ablation (W4A4, `{}`)", b.model),
        &["Method", "ppl c4", "ppl wiki", "epochs", "state KiB", "quant s"],
    );
    for (name, mode, epochs) in rows {
        let mut job = b.job(QuantJob::cbq(BitSpec::w4a4()));
        job.rounding = mode;
        job.epochs = epochs;
        let (c4, wiki, secs, s) = b.run_ppl(&mut pipe, &job);
        t.row(&[name.into(), fmt_f(c4, 2), fmt_f(wiki, 2), epochs.to_string(),
                (s.state_bytes / 1024).to_string(), fmt_f(secs, 1)]);
    }
    t.print();
}

/// Tables 3c / 7 / 8 / 9: CBD window x overlap grid with cost columns.
fn table3c(b: &Bench) {
    let rt = b.rt();
    let mut pipe = b.pipe(&rt);
    let windows = b.art.manifest.windows[&b.model].clone();
    for bits in [BitSpec::w4a4(), BitSpec::w2a16()] {
        let mut t = Table::new(
            format!("Table 3c/7/9 — CBD ablation ({}, `{}`)", bits.label(), b.model),
            &["#blocks", "overlap", "ppl c4", "ppl wiki", "time s", "state KiB", "act-cache KiB"],
        );
        for &w in &windows {
            if w > pipe.cfg.n_layers {
                continue;
            }
            let overlaps: Vec<usize> = match w {
                1 => vec![0],
                2 => vec![0, 1],
                4 => vec![0, 1, 2, 3],
                _ => vec![0, w / 2, w - 1],
            };
            for ov in overlaps {
                let mut job = b.job(QuantJob::cbq(bits.clone()));
                job.window = w;
                job.overlap = ov;
                let (c4, wiki, secs, s) = b.run_ppl(&mut pipe, &job);
                t.row(&[w.to_string(), ov.to_string(), fmt_f(c4, 2), fmt_f(wiki, 2),
                        fmt_f(secs, 1), (s.state_bytes / 1024).to_string(),
                        (s.act_cache_bytes / 1024).to_string()]);
            }
        }
        t.print();
    }
}

/// Table 5: reconstruction-loss ablation (L2 / KLD / both).
fn table5(b: &Bench) {
    let rt = b.rt();
    let mut pipe = b.pipe(&rt);
    let rows: Vec<(&str, f32, f32)> =
        vec![("L2 only", 1.0, 0.0), ("KLD only", 0.0, 1.0), ("L2 + KLD", 1.0, 1.0)];
    let mut t = Table::new(
        format!("Table 5 — loss ablation (W4A4, `{}`)", b.model),
        &["Loss", "ppl c4", "ppl wiki"],
    );
    for (name, l2, kld) in rows {
        let mut job = b.job(QuantJob::cbq(BitSpec::w4a4()));
        job.l2_weight = l2;
        job.kld_weight = kld;
        let (c4, wiki, _, _) = b.run_ppl(&mut pipe, &job);
        t.row(&[name.into(), fmt_f(c4, 2), fmt_f(wiki, 2)]);
    }
    t.print();
}

/// Table 11: quantization wall-clock, CBQ vs OmniQuant-like, across sizes.
fn table11(b: &Bench) {
    let mut t = Table::new(
        "Table 11 — quantization time (s), weight-only W4A16",
        &["model", "quant params", "OmniQ-like", "CBQ"],
    );
    for name in ["t", "s", "m"] {
        if !b.art.manifest.configs.contains_key(name) {
            continue;
        }
        let rt = b.rt();
        let mut pipe = Pipeline::new(&b.art, &rt, name).unwrap();
        let mut cells = vec![name.to_string(), pipe.cfg.quant_params().to_string()];
        for job in [
            b.job(QuantJob::omniquant_like(BitSpec::w4a16())),
            b.job(QuantJob::cbq(BitSpec::w4a16())),
        ] {
            let t0 = Instant::now();
            let _ = pipe.run(&job).unwrap();
            cells.push(fmt_f(t0.elapsed().as_secs_f64(), 1));
        }
        t.row(&cells);
    }
    t.print();
}

/// Table 12: LoRA-Rounding rank sweep.
fn table12(b: &Bench) {
    let rt = b.rt();
    let mut pipe = b.pipe(&rt);
    let mut t = Table::new(
        format!("Table 12 — LoRA rank sweep (W4A4, `{}`)", b.model),
        &["rank", "ppl c4", "ppl wiki"],
    );
    for rank in [3usize, 4, 5, 6, 7] {
        let mut job = b.job(QuantJob::cbq(BitSpec::w4a4()));
        job.rank = rank;
        let (c4, wiki, _, _) = b.run_ppl(&mut pipe, &job);
        t.row(&[rank.to_string(), fmt_f(c4, 2), fmt_f(wiki, 2)]);
    }
    t.print();
}

/// Table 13: model-size series (the OPT-family analog).
fn table13(b: &Bench) {
    let mut t = Table::new(
        "Table 13 — model-size series, perplexity",
        &["model", "#Bits", "Method", "synth-c4", "synth-wiki"],
    );
    for name in ["t", "s", "m"] {
        if !b.art.manifest.configs.contains_key(name) {
            continue;
        }
        let rt = b.rt();
        let mut pipe = Pipeline::new(&b.art, &rt, name).unwrap();
        let fp = pipe.fp_model();
        t.row(&[name.into(), "FP".into(), "-".into(),
            fmt_f(pipe.perplexity(&fp, Style::C4, b.eval_batches).unwrap(), 2),
            fmt_f(pipe.perplexity(&fp, Style::Wiki, b.eval_batches).unwrap(), 2)]);
        for (bits, method, job) in [
            ("W4A16", "GPTQ", b.job(QuantJob::gptq(BitSpec::w4a16()))),
            ("W4A16", "CBQ", b.job(QuantJob::cbq(BitSpec::w4a16()))),
            ("W2A16", "OmniQ-like", b.job(QuantJob::omniquant_like(BitSpec::w2a16()))),
            ("W2A16", "CBQ", b.job(QuantJob::cbq(BitSpec::w2a16()))),
        ] {
            let (m, _) = pipe.run(&job).unwrap();
            t.row(&[name.into(), bits.into(), method.into(),
                fmt_f(pipe.perplexity(&m, Style::C4, b.eval_batches).unwrap(), 2),
                fmt_f(pipe.perplexity(&m, Style::Wiki, b.eval_batches).unwrap(), 2)]);
        }
    }
    t.print();
}

/// Table 14: W6A6.
fn table14(b: &Bench) {
    let rt = b.rt();
    let mut pipe = b.pipe(&rt);
    let mut t = Table::new(
        format!("Table 14 — W6A6, model `{}`", b.model),
        &["Method", "ppl c4", "ppl wiki"],
    );
    let fp = pipe.fp_model();
    t.row(&["FP".into(),
        fmt_f(pipe.perplexity(&fp, Style::C4, b.eval_batches).unwrap(), 2),
        fmt_f(pipe.perplexity(&fp, Style::Wiki, b.eval_batches).unwrap(), 2)]);
    for (name, job) in [
        ("OmniQ-like", b.job(QuantJob::omniquant_like(BitSpec::w6a6()))),
        ("CBQ", b.job(QuantJob::cbq(BitSpec::w6a6()))),
    ] {
        let (c4, wiki, _, _) = b.run_ppl(&mut pipe, &job);
        t.row(&[name.into(), fmt_f(c4, 2), fmt_f(wiki, 2)]);
    }
    t.print();
}

/// Table 15: CFP-only vs CBD-only contribution split at W4A16.
fn table15(b: &Bench) {
    let rt = b.rt();
    let mut pipe = b.pipe(&rt);
    let mut t = Table::new(
        format!("Table 15 — CFP vs CBD at W4A16, model `{}`", b.model),
        &["Config", "ppl c4", "ppl wiki"],
    );
    // CFP only: preproc + RTN
    let mut cfp_only = b.job(QuantJob::rtn(BitSpec::w4a16()));
    cfp_only.preproc = PreprocMethod::CfpFull;
    // CBD only: reconstruction without preprocessing
    let mut cbd_only = b.job(QuantJob::cbq(BitSpec::w4a16()));
    cbd_only.preproc = PreprocMethod::None;
    for (name, job) in [("CFP", cfp_only), ("CBD", cbd_only)] {
        let (c4, wiki, _, _) = b.run_ppl(&mut pipe, &job);
        t.row(&[name.into(), fmt_f(c4, 2), fmt_f(wiki, 2)]);
    }
    t.print();
}

// ---------------------------------------------------------------------------

fn main() {
    let b = Bench::new();
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let all: Vec<(&str, fn(&Bench))> = vec![
        ("table1", table1),
        ("table2", table2),
        ("table3a", table3a),
        ("table3b", table3b),
        ("table3c", table3c),
        ("table5", table5),
        ("table11", table11),
        ("table12", table12),
        ("table13", table13),
        ("table14", table14),
        ("table15", table15),
    ];
    let selected: Vec<&(&str, fn(&Bench))> = if args.is_empty() {
        all.iter().collect()
    } else {
        all.iter().filter(|(n, _)| args.iter().any(|a| a == n)).collect()
    };
    println!(
        "benching {} tables on model `{}` (calib={}, eval={}, items={})",
        selected.len(),
        b.model,
        b.calib,
        b.eval_batches,
        b.items
    );
    for (name, f) in selected {
        let t0 = Instant::now();
        println!("\n################ {name} ################");
        f(&b);
        println!("[{name} took {:.1}s]", t0.elapsed().as_secs_f64());
    }
}
